//! `coordinator::obsv` — lock-light serving observability.
//!
//! Three layers, all allocation-free on the hot path:
//!
//! 1. **Metrics registry** ([`ServingRegistry`]): atomic [`Counter`]s,
//!    [`Gauge`]s, indexed [`CounterVec`]s, and fixed-bucket log2
//!    [`Histogram`]s that the server/edge/shard/reactor code increments
//!    directly — no `Mutex<ServingStats>` on the request path. A
//!    [`ServingStats`] snapshot is re-layered on top at read time.
//!
//! 2. **Span tracing** ([`Tracer`]): a sampled per-request stage
//!    breakdown (admit → queue → edge → pack → uplink → dispatch →
//!    cloud → respond) carried through the pipeline as a [`SpanTag`]
//!    and finished exactly once at every terminal answer site. Shed and
//!    error outcomes always emit, sampled or not. Finished spans land
//!    in a bounded ring buffer and export as Chrome trace-event JSON
//!    ([`chrome_trace`], loadable in Perfetto / `chrome://tracing`).
//!
//! 3. **Snapshot consistency**: writers bump *totals before components*
//!    (`requests` before `shard_requests[i]`, `offered` before the
//!    queue push) with sequentially-consistent RMWs, and
//!    [`ServingRegistry::snapshot`] reads *components before totals*,
//!    so any mid-run snapshot satisfies the accounting invariants
//!    (`Σ shard_requests ≤ requests`, `requests + shed ≤ offered`) —
//!    the field-by-field mutex-clone path could not promise that.

use super::metrics::{LatencyHistogram, ServingStats};
use crate::util::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Mutex;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// primitives

/// Monotonic atomic counter (u64). `dec` exists for the one
/// compensation site (admission `Closed` un-offers a request).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, SeqCst);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, SeqCst);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.0.load(SeqCst)
    }
}

/// Last-write-wins atomic gauge (u64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, SeqCst);
    }

    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.0.load(SeqCst)
    }
}

/// Fixed-width family of counters indexed by id (shard, edge worker,
/// plan). Out-of-range increments clamp to the last slot rather than
/// panic — ids are structurally bounded, this is belt-and-braces.
#[derive(Debug)]
pub struct CounterVec(Box<[AtomicU64]>);

impl CounterVec {
    pub fn new(len: usize) -> Self {
        CounterVec((0..len.max(1)).map(|_| AtomicU64::new(0)).collect())
    }

    pub fn inc(&self, i: usize) {
        self.add(i, 1);
    }

    pub fn add(&self, i: usize, n: u64) {
        self.0[i.min(self.0.len() - 1)].fetch_add(n, SeqCst);
    }

    pub fn get(&self, i: usize) -> u64 {
        self.0[i.min(self.0.len() - 1)].load(SeqCst)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn snapshot(&self) -> Vec<u64> {
        self.0.iter().map(|c| c.load(SeqCst)).collect()
    }
}

// ---------------------------------------------------------------------------
// atomic log2 histogram

/// `16 + 60×16`: exact buckets for 0..15 ns, then 16 linear sub-buckets
/// per power of two for exponents 4..=63.
const HIST_BUCKETS: usize = 16 + 60 * 16;

/// Lock-free duration histogram over nanoseconds: values below 16 ns
/// get exact buckets, larger values get 16 linear sub-buckets per
/// power of two (≤ 1/16 ≈ 6% relative quantile error), covering the
/// full u64 range. Mergeable and snapshot-consistent: quantiles are
/// computed against the bucket sum observed in one pass, never against
/// a separately-read count.
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

fn bucket_index(ns: u64) -> usize {
    if ns < 16 {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros() as usize; // ≥ 4
    let sub = ((ns >> (e - 4)) & 0xF) as usize;
    16 + (e - 4) * 16 + sub
}

/// Midpoint of the bucket's value range, in nanoseconds.
fn bucket_mid_ns(idx: usize) -> f64 {
    if idx < 16 {
        return idx as f64;
    }
    let b = idx - 16;
    let e = b / 16 + 4;
    let sub = (b % 16) as u64;
    let width = 1u64 << (e - 4);
    ((16 + sub) * width) as f64 + width as f64 / 2.0
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record a duration given in seconds. NaN is ignored (an undefined
    /// duration must not shift quantiles toward zero), negatives clamp
    /// to zero, and +inf clamps to the top bucket.
    pub fn record_secs(&self, s: f64) {
        if s.is_nan() {
            return;
        }
        let ns = (s.max(0.0) * 1e9).min(u64::MAX as f64) as u64;
        self.record_ns(ns);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, SeqCst);
        self.sum_ns.fetch_add(ns, SeqCst);
        self.max_ns.fetch_max(ns, SeqCst);
        self.count.fetch_add(1, SeqCst);
    }

    pub fn count(&self) -> u64 {
        self.count.load(SeqCst)
    }

    /// One-pass consistent snapshot of the bucket state.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(SeqCst)).collect(),
            sum_ns: self.sum_ns.load(SeqCst),
            max_ns: self.max_ns.load(SeqCst),
        }
    }
}

/// Plain (non-atomic) copy of a [`Histogram`]'s state: quantiles,
/// moments, and lossless merging.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    sum_ns: u64,
    max_ns: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns as f64 / n as f64 / 1e9
        }
    }

    pub fn max(&self) -> f64 {
        self.max_ns as f64 / 1e9
    }

    /// Approximate quantile in seconds; `None` when empty (so empty
    /// histograms serialize as `null`, not a fake `0`).
    pub fn quantile_opt(&self, q: f64) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(bucket_mid_ns(i) / 1e9);
            }
        }
        Some(self.max())
    }

    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_opt(q).unwrap_or(0.0)
    }

    /// Bucket-wise merge (associative and commutative: the layouts are
    /// identical by construction).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Re-layer onto the legacy log10 [`LatencyHistogram`] (what
    /// [`ServingStats`] reports): bucket counts map through each log2
    /// bucket's midpoint, then the exact sum/max moments are restored
    /// so `mean()`/`max()` stay lossless.
    pub fn to_latency_histogram(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::default();
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                h.record_n(bucket_mid_ns(i) / 1e9, c);
            }
        }
        h.set_exact_moments(self.sum_ns as f64 / 1e9, self.max_ns as f64 / 1e9);
        h
    }
}

// ---------------------------------------------------------------------------
// serving registry

/// The atomic counter set behind [`ServingStats`]. Request-path code
/// holds an `Arc<ServingRegistry>` and increments handles directly;
/// [`ServingRegistry::snapshot`] assembles a consistent `ServingStats`.
///
/// Writer protocol (the snapshot-monotonicity contract): bump the
/// *total* before its *components* — `requests` before
/// `shard_requests[i]`/`tx_bytes_total`, `offered` before handing the
/// request to the queue. The snapshot reads components first and
/// totals last, so the invariants `Σ shard_requests ≤ requests` and
/// `requests + shed ≤ offered` hold in every mid-run snapshot.
#[derive(Debug)]
pub struct ServingRegistry {
    pub e2e: Histogram,
    pub edge: Histogram,
    pub net: Histogram,
    pub cloud: Histogram,
    pub queue: Histogram,
    pub requests: Counter,
    pub batches: Counter,
    pub tx_bytes_total: Counter,
    pub offered: Counter,
    pub shed: Counter,
    pub batch_slo_closes: Counter,
    pub shard_batches: CounterVec,
    pub shard_requests: CounterVec,
    pub edge_requests: CounterVec,
    pub plan_requests: CounterVec,
    pub plan_switches: Counter,
    pub mid_batch_swaps: Counter,
}

impl ServingRegistry {
    /// Registry sized for the pipeline shape: cloud shards × edge
    /// workers × banked plans.
    pub fn sized(shards: usize, edge_workers: usize, plans: usize) -> Self {
        ServingRegistry {
            e2e: Histogram::default(),
            edge: Histogram::default(),
            net: Histogram::default(),
            cloud: Histogram::default(),
            queue: Histogram::default(),
            requests: Counter::default(),
            batches: Counter::default(),
            tx_bytes_total: Counter::default(),
            offered: Counter::default(),
            shed: Counter::default(),
            batch_slo_closes: Counter::default(),
            shard_batches: CounterVec::new(shards),
            shard_requests: CounterVec::new(shards),
            edge_requests: CounterVec::new(edge_workers),
            plan_requests: CounterVec::new(plans),
            plan_switches: Counter::default(),
            mid_batch_swaps: Counter::default(),
        }
    }

    /// Consistent point-in-time [`ServingStats`]. Components are read
    /// before their totals (see the struct docs); wall clock, queue
    /// depth, pool, adaptive, and TCP fields are left at default for
    /// the caller (`Server::stats`) to fill from their owners.
    pub fn snapshot(&self) -> ServingStats {
        let mut s =
            ServingStats::sized(self.shard_requests.len(), self.edge_requests.len(), self.plan_requests.len());
        // components first…
        s.e2e = self.e2e.snapshot().to_latency_histogram();
        s.edge = self.edge.snapshot().to_latency_histogram();
        s.net = self.net.snapshot().to_latency_histogram();
        s.cloud = self.cloud.snapshot().to_latency_histogram();
        s.queue = self.queue.snapshot().to_latency_histogram();
        s.shard_batches = self.shard_batches.snapshot();
        s.shard_requests = self.shard_requests.snapshot();
        s.edge_requests = self.edge_requests.snapshot();
        s.plan_requests = self.plan_requests.snapshot();
        s.plan_switches = self.plan_switches.get();
        s.mid_batch_swaps = self.mid_batch_swaps.get();
        s.batch_slo_closes = self.batch_slo_closes.get();
        s.tx_bytes_total = self.tx_bytes_total.get();
        s.batches = self.batches.get();
        // …totals last, least- to most-inclusive.
        s.requests = self.requests.get();
        s.shed = self.shed.get();
        s.offered = self.offered.get();
        s
    }
}

// ---------------------------------------------------------------------------
// span tracing

/// Pipeline stages, in request order. Indexes into
/// [`SpanTag::stage_ns`].
pub const STAGE_NAMES: [&str; 8] =
    ["admit", "queue", "edge", "pack", "uplink", "dispatch", "cloud", "respond"];
pub const STAGE_ADMIT: usize = 0;
pub const STAGE_QUEUE: usize = 1;
pub const STAGE_EDGE: usize = 2;
pub const STAGE_PACK: usize = 3;
pub const STAGE_UPLINK: usize = 4;
pub const STAGE_DISPATCH: usize = 5;
pub const STAGE_CLOUD: usize = 6;
pub const STAGE_RESPOND: usize = 7;

/// Terminal outcome of a traced request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Done,
    Shed,
    Error,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Done => "done",
            SpanKind::Shed => "shed",
            SpanKind::Error => "error",
        }
    }
}

/// Per-request trace context, created at admission and carried through
/// the pipeline (`Request` → `SentPacket` → `CloudJob`). Stage
/// durations are filled in as each stage's measured time becomes
/// known; [`Tracer::finish`] turns the tag into a [`SpanRecord`].
#[derive(Debug, Clone)]
pub struct SpanTag {
    pub id: u64,
    pub sampled: bool,
    /// Admission time, nanoseconds since the tracer epoch.
    pub t0_ns: u64,
    /// Per-stage duration, nanoseconds (see `STAGE_*`).
    pub stage_ns: [u64; 8],
}

impl SpanTag {
    pub fn set_stage(&mut self, stage: usize, d: Duration) {
        self.stage_ns[stage] = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
    }

    pub fn set_stage_secs(&mut self, stage: usize, s: f64) {
        if s.is_finite() && s > 0.0 {
            self.stage_ns[stage] = (s * 1e9).min(u64::MAX as f64) as u64;
        }
    }

    pub fn total_ns(&self) -> u64 {
        self.stage_ns.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }
}

/// A finished span in the ring buffer.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub id: u64,
    pub kind: SpanKind,
    pub t0_ns: u64,
    pub stage_ns: [u64; 8],
}

/// Trace configuration carried by `ServeConfig`.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Keep 1-in-N sampled spans; 0 disables tracing entirely (no tags
    /// are allocated). Shed/error spans are kept regardless of the
    /// sample once tracing is on.
    pub sample: u64,
    /// Ring-buffer capacity; the oldest spans are dropped (and
    /// counted) once full.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { sample: 0, capacity: 65536 }
    }
}

/// Span source + bounded sink. `begin` is called once per admitted
/// request; `finish` exactly once at the request's terminal answer
/// site (completed, shed, or errored) — so at `sample: 1` the exported
/// span count equals completed + shed + errors, the telemetry
/// extension of the exactly-once answering contract.
#[derive(Debug)]
pub struct Tracer {
    sample: u64,
    capacity: usize,
    epoch: Instant,
    next_id: AtomicU64,
    ring: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Self {
        Tracer {
            sample: cfg.sample,
            capacity: cfg.capacity.max(1),
            epoch: Instant::now(),
            next_id: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.sample > 0
    }

    /// Start a span; `None` when tracing is off. Every admitted request
    /// gets a tag when tracing is on (unsampled tags still emit on
    /// shed/error — those are the spans worth keeping).
    pub fn begin(&self) -> Option<Box<SpanTag>> {
        if self.sample == 0 {
            return None;
        }
        let id = self.next_id.fetch_add(1, SeqCst);
        Some(Box::new(SpanTag {
            id,
            sampled: id % self.sample == 0,
            t0_ns: u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX),
            stage_ns: [0; 8],
        }))
    }

    /// Terminal sink: emit the span if it is sampled or non-`Done`.
    pub fn finish(&self, tag: Option<Box<SpanTag>>, kind: SpanKind) {
        let Some(tag) = tag else { return };
        if !tag.sampled && kind == SpanKind::Done {
            return;
        }
        let rec = SpanRecord { id: tag.id, kind, t0_ns: tag.t0_ns, stage_ns: tag.stage_ns };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, SeqCst);
        }
        ring.push_back(rec);
    }

    /// Drain all buffered spans (oldest first).
    pub fn drain(&self) -> Vec<SpanRecord> {
        self.ring.lock().unwrap().drain(..).collect()
    }

    /// Spans evicted from a full ring.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(SeqCst)
    }
}

/// Render finished spans as Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` envelope Perfetto and `chrome://tracing`
/// load directly): one complete ("X") event per stage plus one
/// request-level envelope event carrying the outcome, stages laid out
/// end-to-end from the admission timestamp. Stage times here are the
/// pipeline's *accounted* durations (Virtual delay mode charges
/// modeled wire/edge time), so the trace shows the latency
/// decomposition the split planner reasons about.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let mut events = Vec::with_capacity(spans.len() * 9);
    for sp in spans {
        let us = |ns: u64| ns as f64 / 1e3;
        events.push(Json::Obj(
            [
                ("name".to_string(), Json::Str(sp.kind.as_str().into())),
                ("cat".to_string(), Json::Str("request".into())),
                ("ph".to_string(), Json::Str("X".into())),
                ("pid".to_string(), Json::Num(0.0)),
                ("tid".to_string(), Json::Num(sp.id as f64)),
                ("ts".to_string(), Json::Num(us(sp.t0_ns))),
                ("dur".to_string(), Json::Num(us(sp.stage_ns.iter().sum()))),
            ]
            .into_iter()
            .collect(),
        ));
        let mut at = sp.t0_ns;
        for (i, &dur) in sp.stage_ns.iter().enumerate() {
            events.push(Json::Obj(
                [
                    ("name".to_string(), Json::Str(STAGE_NAMES[i].into())),
                    ("cat".to_string(), Json::Str("stage".into())),
                    ("ph".to_string(), Json::Str("X".into())),
                    ("pid".to_string(), Json::Num(0.0)),
                    ("tid".to_string(), Json::Num(sp.id as f64)),
                    ("ts".to_string(), Json::Num(us(at))),
                    ("dur".to_string(), Json::Num(us(dur))),
                ]
                .into_iter()
                .collect(),
            ));
            at = at.saturating_add(dur);
        }
    }
    Json::Obj(
        [
            ("traceEvents".to_string(), Json::Arr(events)),
            ("displayTimeUnit".to_string(), Json::Str("ms".into())),
        ]
        .into_iter()
        .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn histogram_sub_resolution_and_zero() {
        let h = Histogram::default();
        h.record(Duration::ZERO);
        h.record(Duration::from_nanos(3));
        h.record(Duration::from_nanos(15));
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        // sub-16ns values land in their exact buckets
        assert!(s.quantile(0.01) <= 16e-9, "{}", s.quantile(0.01));
        assert!((s.mean() - 6e-9).abs() < 1e-12);
        assert_eq!(s.max(), 15e-9);
    }

    #[test]
    fn histogram_negative_nan_inf() {
        let h = Histogram::default();
        h.record_secs(f64::NAN); // ignored
        h.record_secs(-5.0); // clamps to 0
        h.record_secs(f64::INFINITY); // clamps to the top bucket
        let s = h.snapshot();
        assert_eq!(s.count(), 2, "NaN must not be counted");
        assert!(s.quantile(0.99) > 1e9, "inf must land in the top bucket");
        assert_eq!(s.quantile_opt(0.01).unwrap(), 0.0, "negative clamps to zero");
    }

    #[test]
    fn histogram_quantile_accuracy() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        let p99 = s.quantile(0.99);
        // ≤ 1/16 relative bucket error
        assert!((p50 - 500e-6).abs() / 500e-6 < 0.07, "{p50}");
        assert!((p99 - 990e-6).abs() / 990e-6 < 0.07, "{p99}");
        assert!(p50 <= p99);
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn empty_quantile_is_none_and_serializes_null() {
        let s = Histogram::default().snapshot();
        assert!(s.quantile_opt(0.5).is_none());
        assert_eq!(s.quantile(0.5), 0.0);
        let j = Json::Obj(
            [("p50".to_string(), s.quantile_opt(0.5).map(Json::Num).unwrap_or(Json::Null))]
                .into_iter()
                .collect(),
        );
        assert!(j.to_string_pretty().contains("null"), "{}", j.to_string_pretty());
    }

    #[test]
    fn merge_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::default();
            for &v in vals {
                h.record_ns(v);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(&[10, 2000]), mk(&[50_000]), mk(&[7, 1_000_000, 12]));
        // (a ∪ b) ∪ c == a ∪ (b ∪ c)
        let mut ab = a.clone();
        ab.merge(&b);
        ab.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab.count(), a_bc.count());
        assert_eq!(ab.sum_ns, a_bc.sum_ns);
        assert_eq!(ab.max_ns, a_bc.max_ns);
        assert_eq!(ab.buckets, a_bc.buckets);
        for q in [0.1, 0.5, 0.9, 0.999] {
            assert_eq!(ab.quantile(q), a_bc.quantile(q));
        }
    }

    #[test]
    fn to_latency_histogram_preserves_moments() {
        let h = Histogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        let lat = h.snapshot().to_latency_histogram();
        assert_eq!(lat.count(), 2);
        assert!((lat.mean() - 0.02).abs() < 1e-9, "{}", lat.mean());
        assert!((lat.max() - 0.03).abs() < 1e-9);
        // quantile within the coarser log10 bucket resolution
        let p50 = lat.quantile(0.5);
        assert!((5e-3..2e-2).contains(&p50), "{p50}");
    }

    #[test]
    fn registry_snapshot_is_monotonic_under_concurrent_writes() {
        let reg = Arc::new(ServingRegistry::sized(2, 1, 1));
        let stop = Arc::new(AtomicU64::new(0));
        let writers: Vec<_> = (0..2)
            .map(|shard| {
                let reg = Arc::clone(&reg);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while stop.load(SeqCst) == 0 {
                        // writer protocol: totals before components
                        reg.offered.inc();
                        if n % 7 == 0 {
                            reg.shed.inc();
                        } else {
                            reg.requests.inc();
                            reg.shard_requests.inc(shard);
                        }
                        n += 1;
                    }
                })
            })
            .collect();
        for _ in 0..500 {
            let s = reg.snapshot();
            let shard_sum: u64 = s.shard_requests.iter().sum();
            assert!(
                shard_sum <= s.requests,
                "per-shard sum {shard_sum} exceeds total {}",
                s.requests
            );
            assert!(
                s.requests + s.shed <= s.offered,
                "requests {} + shed {} exceed offered {}",
                s.requests,
                s.shed,
                s.offered
            );
        }
        stop.store(1, SeqCst);
        for w in writers {
            w.join().unwrap();
        }
    }

    #[test]
    fn tracer_sampling_and_always_on_errors() {
        let t = Tracer::new(TraceConfig { sample: 4, capacity: 1024 });
        for i in 0..100u64 {
            let tag = t.begin();
            assert!(tag.is_some());
            let kind = if i % 10 == 9 { SpanKind::Shed } else { SpanKind::Done };
            t.finish(tag, kind);
        }
        let spans = t.drain();
        let done = spans.iter().filter(|s| s.kind == SpanKind::Done).count();
        let shed = spans.iter().filter(|s| s.kind == SpanKind::Shed).count();
        assert_eq!(shed, 10, "shed spans are always kept");
        // 25 sampled ids (0,4,..96), of which ids 39,79 are... none: shed ids
        // are 9,19,..99 — disjoint from the 1-in-4 sample — so 25 done spans.
        assert_eq!(done, 25, "1-in-4 sampling keeps 25 of 100");
    }

    #[test]
    fn tracer_sample_one_is_exactly_once() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 1024 });
        for _ in 0..50 {
            let tag = t.begin();
            t.finish(tag, SpanKind::Done);
        }
        assert_eq!(t.drain().len(), 50);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn tracer_ring_bounded() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 8 });
        for _ in 0..20 {
            t.finish(t.begin(), SpanKind::Done);
        }
        assert_eq!(t.drain().len(), 8);
        assert_eq!(t.dropped(), 12);
    }

    #[test]
    fn disabled_tracer_emits_nothing() {
        let t = Tracer::new(TraceConfig::default());
        assert!(!t.enabled());
        assert!(t.begin().is_none());
        t.finish(None, SpanKind::Error);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn chrome_trace_roundtrips_through_json() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 16 });
        let mut tag = t.begin().unwrap();
        tag.set_stage(STAGE_QUEUE, Duration::from_micros(120));
        tag.set_stage_secs(STAGE_EDGE, 3.5e-3);
        tag.set_stage_secs(STAGE_UPLINK, f64::NAN); // ignored
        t.finish(Some(tag), SpanKind::Done);
        let spans = t.drain();
        let doc = chrome_trace(&spans).to_string_pretty();
        let parsed = Json::parse(&doc).expect("chrome trace must be valid JSON");
        match parsed {
            Json::Obj(o) => match o.get("traceEvents") {
                Some(Json::Arr(evs)) => {
                    assert_eq!(evs.len(), 9, "1 request envelope + 8 stage events");
                }
                other => panic!("traceEvents missing: {other:?}"),
            },
            other => panic!("not an object: {other:?}"),
        }
    }

    #[test]
    fn counter_vec_clamps_out_of_range() {
        let v = CounterVec::new(2);
        v.inc(0);
        v.inc(7); // clamps to last slot
        assert_eq!(v.get(0), 1);
        assert_eq!(v.get(1), 1);
        assert_eq!(v.snapshot(), vec![1, 1]);
    }

    #[test]
    fn gauge_and_counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        c.dec();
        assert_eq!(c.get(), 4);
        let g = Gauge::default();
        g.set(9);
        g.max(3);
        g.max(12);
        assert_eq!(g.get(), 12);
    }
}
