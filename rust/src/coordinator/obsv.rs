//! `coordinator::obsv` — lock-light serving observability.
//!
//! Three layers, all allocation-free on the hot path:
//!
//! 1. **Metrics registry** ([`ServingRegistry`]): atomic [`Counter`]s,
//!    [`Gauge`]s, indexed [`CounterVec`]s, and fixed-bucket log2
//!    [`Histogram`]s that the server/edge/shard/reactor code increments
//!    directly — no `Mutex<ServingStats>` on the request path. A
//!    [`ServingStats`] snapshot is re-layered on top at read time.
//!
//! 2. **Span tracing** ([`Tracer`]): a sampled per-request stage
//!    breakdown (admit → queue → edge → pack → uplink → dispatch →
//!    cloud → respond) carried through the pipeline as a [`SpanTag`]
//!    and finished exactly once at every terminal answer site. Shed and
//!    error outcomes always emit, sampled or not. Finished spans land
//!    in a bounded ring buffer and export as Chrome trace-event JSON
//!    ([`chrome_trace`], loadable in Perfetto / `chrome://tracing`).
//!
//! 3. **Snapshot consistency**: writers bump *totals before components*
//!    (`requests` before `shard_requests[i]`, `offered` before the
//!    queue push) with sequentially-consistent RMWs, and
//!    [`ServingRegistry::snapshot`] reads *components before totals*,
//!    so any mid-run snapshot satisfies the accounting invariants
//!    (`Σ shard_requests ≤ requests`, `requests + shed ≤ offered`) —
//!    the field-by-field mutex-clone path could not promise that.

use super::metrics::{LatencyHistogram, ServingStats};
use crate::util::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// The histogram primitive lives in `util::hist` (the runtime op
// profiler records into it too, and `runtime` must not depend on the
// coordinator); re-exported here so observability call sites keep one
// import path.
pub use crate::util::hist::{HistSnapshot, Histogram};

// ---------------------------------------------------------------------------
// primitives

/// Monotonic atomic counter (u64). `dec` exists for the one
/// compensation site (admission `Closed` un-offers a request).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, SeqCst);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, SeqCst);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.0.load(SeqCst)
    }
}

/// Last-write-wins atomic gauge (u64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, SeqCst);
    }

    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, SeqCst);
    }

    pub fn get(&self) -> u64 {
        self.0.load(SeqCst)
    }
}

/// Fixed-width family of counters indexed by id (shard, edge worker,
/// plan). Out-of-range increments clamp to the last slot rather than
/// panic — ids are structurally bounded, this is belt-and-braces.
#[derive(Debug)]
pub struct CounterVec(Box<[AtomicU64]>);

impl CounterVec {
    pub fn new(len: usize) -> Self {
        CounterVec((0..len.max(1)).map(|_| AtomicU64::new(0)).collect())
    }

    pub fn inc(&self, i: usize) {
        self.add(i, 1);
    }

    pub fn add(&self, i: usize, n: u64) {
        self.0[i.min(self.0.len() - 1)].fetch_add(n, SeqCst);
    }

    pub fn get(&self, i: usize) -> u64 {
        self.0[i.min(self.0.len() - 1)].load(SeqCst)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn snapshot(&self) -> Vec<u64> {
        self.0.iter().map(|c| c.load(SeqCst)).collect()
    }
}

// ---------------------------------------------------------------------------
// serving registry

/// The atomic counter set behind [`ServingStats`]. Request-path code
/// holds an `Arc<ServingRegistry>` and increments handles directly;
/// [`ServingRegistry::snapshot`] assembles a consistent `ServingStats`.
///
/// Writer protocol (the snapshot-monotonicity contract): bump the
/// *total* before its *components* — `requests` before
/// `shard_requests[i]`/`tx_bytes_total`, `offered` before handing the
/// request to the queue. The snapshot reads components first and
/// totals last, so the invariants `Σ shard_requests ≤ requests` and
/// `requests + shed ≤ offered` hold in every mid-run snapshot.
#[derive(Debug)]
pub struct ServingRegistry {
    pub e2e: Histogram,
    pub edge: Histogram,
    pub net: Histogram,
    pub cloud: Histogram,
    pub queue: Histogram,
    pub requests: Counter,
    pub batches: Counter,
    pub tx_bytes_total: Counter,
    pub offered: Counter,
    pub shed: Counter,
    pub batch_slo_closes: Counter,
    pub shard_batches: CounterVec,
    pub shard_requests: CounterVec,
    pub edge_requests: CounterVec,
    pub plan_requests: CounterVec,
    pub plan_switches: Counter,
    pub mid_batch_swaps: Counter,
    /// Cloud engines compiled on demand (lazy loads + post-eviction
    /// reloads), summed across shards.
    pub engine_loads: Counter,
    /// Cloud engines dropped by the per-shard `--engine-cache` LRU.
    pub engine_evictions: Counter,
}

impl ServingRegistry {
    /// Registry sized for the pipeline shape: cloud shards × edge
    /// workers × banked plans.
    pub fn sized(shards: usize, edge_workers: usize, plans: usize) -> Self {
        ServingRegistry {
            e2e: Histogram::default(),
            edge: Histogram::default(),
            net: Histogram::default(),
            cloud: Histogram::default(),
            queue: Histogram::default(),
            requests: Counter::default(),
            batches: Counter::default(),
            tx_bytes_total: Counter::default(),
            offered: Counter::default(),
            shed: Counter::default(),
            batch_slo_closes: Counter::default(),
            shard_batches: CounterVec::new(shards),
            shard_requests: CounterVec::new(shards),
            edge_requests: CounterVec::new(edge_workers),
            plan_requests: CounterVec::new(plans),
            plan_switches: Counter::default(),
            mid_batch_swaps: Counter::default(),
            engine_loads: Counter::default(),
            engine_evictions: Counter::default(),
        }
    }

    /// Consistent point-in-time [`ServingStats`]. Components are read
    /// before their totals (see the struct docs); wall clock, queue
    /// depth, pool, adaptive, and TCP fields are left at default for
    /// the caller (`Server::stats`) to fill from their owners.
    pub fn snapshot(&self) -> ServingStats {
        let mut s =
            ServingStats::sized(self.shard_requests.len(), self.edge_requests.len(), self.plan_requests.len());
        // components first… (`From<HistSnapshot>` is lossless: the
        // snapshot becomes the stats histogram's backing store)
        s.e2e = LatencyHistogram::from(self.e2e.snapshot());
        s.edge = LatencyHistogram::from(self.edge.snapshot());
        s.net = LatencyHistogram::from(self.net.snapshot());
        s.cloud = LatencyHistogram::from(self.cloud.snapshot());
        s.queue = LatencyHistogram::from(self.queue.snapshot());
        s.shard_batches = self.shard_batches.snapshot();
        s.shard_requests = self.shard_requests.snapshot();
        s.edge_requests = self.edge_requests.snapshot();
        s.plan_requests = self.plan_requests.snapshot();
        s.plan_switches = self.plan_switches.get();
        s.mid_batch_swaps = self.mid_batch_swaps.get();
        s.engine_loads = self.engine_loads.get();
        s.engine_evictions = self.engine_evictions.get();
        s.batch_slo_closes = self.batch_slo_closes.get();
        s.tx_bytes_total = self.tx_bytes_total.get();
        s.batches = self.batches.get();
        // …totals last, least- to most-inclusive.
        s.requests = self.requests.get();
        s.shed = self.shed.get();
        s.offered = self.offered.get();
        s
    }
}

// ---------------------------------------------------------------------------
// span tracing

/// Pipeline stages, in request order. Indexes into
/// [`SpanTag::stage_ns`].
pub const STAGE_NAMES: [&str; 8] =
    ["admit", "queue", "edge", "pack", "uplink", "dispatch", "cloud", "respond"];
pub const STAGE_ADMIT: usize = 0;
pub const STAGE_QUEUE: usize = 1;
pub const STAGE_EDGE: usize = 2;
pub const STAGE_PACK: usize = 3;
pub const STAGE_UPLINK: usize = 4;
pub const STAGE_DISPATCH: usize = 5;
pub const STAGE_CLOUD: usize = 6;
pub const STAGE_RESPOND: usize = 7;

/// Terminal outcome of a traced request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Done,
    Shed,
    Error,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Done => "done",
            SpanKind::Shed => "shed",
            SpanKind::Error => "error",
        }
    }
}

/// One profiled runtime op attributed to a pipeline stage of a traced
/// request (filled from the op profiler's capture buffer by the edge
/// and shard threads when both profiling and sampling are on). For a
/// batched cloud execution the batch's ops are attached to every
/// sampled member span — the trace shows the work each request rode.
#[derive(Debug, Clone)]
pub struct StagedOp {
    /// The stage this op executed inside (`STAGE_EDGE`/`STAGE_CLOUD`).
    pub stage: usize,
    /// Op signature (`kind[shape]`), shared with the profiler table.
    pub sig: Arc<str>,
    pub dur_ns: u64,
}

/// Per-request trace context, created at admission and carried through
/// the pipeline (`Request` → `SentPacket` → `CloudJob`). Stage
/// durations are filled in as each stage's measured time becomes
/// known; [`Tracer::finish`] turns the tag into a [`SpanRecord`].
#[derive(Debug, Clone)]
pub struct SpanTag {
    pub id: u64,
    pub sampled: bool,
    /// Admission time, nanoseconds since the tracer epoch.
    pub t0_ns: u64,
    /// Per-stage duration, nanoseconds (see `STAGE_*`).
    pub stage_ns: [u64; 8],
    /// Profiled runtime ops (empty unless `--profile on` and sampled —
    /// no per-request allocation otherwise).
    pub ops: Vec<StagedOp>,
}

impl SpanTag {
    pub fn set_stage(&mut self, stage: usize, d: Duration) {
        self.stage_ns[stage] = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
    }

    pub fn set_stage_secs(&mut self, stage: usize, s: f64) {
        if s.is_finite() && s > 0.0 {
            self.stage_ns[stage] = (s * 1e9).min(u64::MAX as f64) as u64;
        }
    }

    pub fn total_ns(&self) -> u64 {
        self.stage_ns.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }
}

/// A finished span in the ring buffer.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub id: u64,
    pub kind: SpanKind,
    pub t0_ns: u64,
    pub stage_ns: [u64; 8],
    /// Profiled runtime ops attributed to this span (see [`StagedOp`]).
    pub ops: Vec<StagedOp>,
}

/// Trace configuration carried by `ServeConfig`.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Keep 1-in-N sampled spans; 0 disables tracing entirely (no tags
    /// are allocated). Shed/error spans are kept regardless of the
    /// sample once tracing is on.
    pub sample: u64,
    /// Ring-buffer capacity; the oldest spans are dropped (and
    /// counted) once full.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { sample: 0, capacity: 65536 }
    }
}

/// Span source + bounded sink. `begin` is called once per admitted
/// request; `finish` exactly once at the request's terminal answer
/// site (completed, shed, or errored) — so at `sample: 1` the exported
/// span count equals completed + shed + errors, the telemetry
/// extension of the exactly-once answering contract.
#[derive(Debug)]
pub struct Tracer {
    sample: u64,
    capacity: usize,
    epoch: Instant,
    next_id: AtomicU64,
    ring: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Self {
        Tracer {
            sample: cfg.sample,
            capacity: cfg.capacity.max(1),
            epoch: Instant::now(),
            next_id: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.sample > 0
    }

    /// Start a span; `None` when tracing is off. Every admitted request
    /// gets a tag when tracing is on (unsampled tags still emit on
    /// shed/error — those are the spans worth keeping).
    pub fn begin(&self) -> Option<Box<SpanTag>> {
        if self.sample == 0 {
            return None;
        }
        let id = self.next_id.fetch_add(1, SeqCst);
        Some(Box::new(SpanTag {
            id,
            sampled: id % self.sample == 0,
            t0_ns: u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX),
            stage_ns: [0; 8],
            ops: Vec::new(),
        }))
    }

    /// Terminal sink: emit the span if it is sampled or non-`Done`.
    pub fn finish(&self, tag: Option<Box<SpanTag>>, kind: SpanKind) {
        let Some(tag) = tag else { return };
        if !tag.sampled && kind == SpanKind::Done {
            return;
        }
        let rec =
            SpanRecord { id: tag.id, kind, t0_ns: tag.t0_ns, stage_ns: tag.stage_ns, ops: tag.ops };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, SeqCst);
        }
        ring.push_back(rec);
    }

    /// Drain all buffered spans (oldest first).
    pub fn drain(&self) -> Vec<SpanRecord> {
        self.ring.lock().unwrap().drain(..).collect()
    }

    /// Spans evicted from a full ring.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(SeqCst)
    }
}

/// Render finished spans as Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` envelope Perfetto and `chrome://tracing`
/// load directly): one complete ("X") event per stage plus one
/// request-level envelope event carrying the outcome, stages laid out
/// end-to-end from the admission timestamp. Stage times here are the
/// pipeline's *accounted* durations (Virtual delay mode charges
/// modeled wire/edge time), so the trace shows the latency
/// decomposition the split planner reasons about.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let mut events = Vec::with_capacity(spans.len() * 9);
    for sp in spans {
        let us = |ns: u64| ns as f64 / 1e3;
        events.push(Json::Obj(
            [
                ("name".to_string(), Json::Str(sp.kind.as_str().into())),
                ("cat".to_string(), Json::Str("request".into())),
                ("ph".to_string(), Json::Str("X".into())),
                ("pid".to_string(), Json::Num(0.0)),
                ("tid".to_string(), Json::Num(sp.id as f64)),
                ("ts".to_string(), Json::Num(us(sp.t0_ns))),
                ("dur".to_string(), Json::Num(us(sp.stage_ns.iter().sum()))),
            ]
            .into_iter()
            .collect(),
        ));
        let mut starts = [0u64; 8];
        let mut at = sp.t0_ns;
        for (i, &dur) in sp.stage_ns.iter().enumerate() {
            starts[i] = at;
            events.push(Json::Obj(
                [
                    ("name".to_string(), Json::Str(STAGE_NAMES[i].into())),
                    ("cat".to_string(), Json::Str("stage".into())),
                    ("ph".to_string(), Json::Str("X".into())),
                    ("pid".to_string(), Json::Num(0.0)),
                    ("tid".to_string(), Json::Num(sp.id as f64)),
                    ("ts".to_string(), Json::Num(us(at))),
                    ("dur".to_string(), Json::Num(us(dur))),
                ]
                .into_iter()
                .collect(),
            ));
            at = at.saturating_add(dur);
        }
        // profiled runtime ops nest inside their stage's window, laid
        // end-to-end in execution order (cat "op" — absent entirely
        // unless the run profiled, so stage/envelope counts are stable)
        let mut op_at = starts;
        for op in &sp.ops {
            let stage = op.stage.min(7);
            events.push(Json::Obj(
                [
                    ("name".to_string(), Json::Str(op.sig.as_ref().into())),
                    ("cat".to_string(), Json::Str("op".into())),
                    ("ph".to_string(), Json::Str("X".into())),
                    ("pid".to_string(), Json::Num(0.0)),
                    ("tid".to_string(), Json::Num(sp.id as f64)),
                    ("ts".to_string(), Json::Num(us(op_at[stage]))),
                    ("dur".to_string(), Json::Num(us(op.dur_ns))),
                ]
                .into_iter()
                .collect(),
            ));
            op_at[stage] = op_at[stage].saturating_add(op.dur_ns);
        }
    }
    Json::Obj(
        [
            ("traceEvents".to_string(), Json::Arr(events)),
            ("displayTimeUnit".to_string(), Json::Str("ms".into())),
        ]
        .into_iter()
        .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn empty_quantile_serializes_null() {
        let s = Histogram::default().snapshot();
        assert!(s.quantile_opt(0.5).is_none());
        let j = Json::Obj(
            [("p50".to_string(), s.quantile_opt(0.5).map(Json::Num).unwrap_or(Json::Null))]
                .into_iter()
                .collect(),
        );
        assert!(j.to_string_pretty().contains("null"), "{}", j.to_string_pretty());
    }

    #[test]
    fn latency_histogram_view_preserves_moments() {
        let h = Histogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        let lat = LatencyHistogram::from(h.snapshot());
        assert_eq!(lat.count(), 2);
        assert!((lat.mean() - 0.02).abs() < 1e-9, "{}", lat.mean());
        assert!((lat.max() - 0.03).abs() < 1e-9);
        // same bucket scheme as the atomic side: p50 is the 10ms sample
        let p50 = lat.quantile(0.5);
        assert!((p50 - 10e-3).abs() / 10e-3 < 0.07, "{p50}");
    }

    #[test]
    fn registry_snapshot_is_monotonic_under_concurrent_writes() {
        let reg = Arc::new(ServingRegistry::sized(2, 1, 1));
        let stop = Arc::new(AtomicU64::new(0));
        let writers: Vec<_> = (0..2)
            .map(|shard| {
                let reg = Arc::clone(&reg);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while stop.load(SeqCst) == 0 {
                        // writer protocol: totals before components
                        reg.offered.inc();
                        if n % 7 == 0 {
                            reg.shed.inc();
                        } else {
                            reg.requests.inc();
                            reg.shard_requests.inc(shard);
                        }
                        n += 1;
                    }
                })
            })
            .collect();
        for _ in 0..500 {
            let s = reg.snapshot();
            let shard_sum: u64 = s.shard_requests.iter().sum();
            assert!(
                shard_sum <= s.requests,
                "per-shard sum {shard_sum} exceeds total {}",
                s.requests
            );
            assert!(
                s.requests + s.shed <= s.offered,
                "requests {} + shed {} exceed offered {}",
                s.requests,
                s.shed,
                s.offered
            );
        }
        stop.store(1, SeqCst);
        for w in writers {
            w.join().unwrap();
        }
    }

    #[test]
    fn tracer_sampling_and_always_on_errors() {
        let t = Tracer::new(TraceConfig { sample: 4, capacity: 1024 });
        for i in 0..100u64 {
            let tag = t.begin();
            assert!(tag.is_some());
            let kind = if i % 10 == 9 { SpanKind::Shed } else { SpanKind::Done };
            t.finish(tag, kind);
        }
        let spans = t.drain();
        let done = spans.iter().filter(|s| s.kind == SpanKind::Done).count();
        let shed = spans.iter().filter(|s| s.kind == SpanKind::Shed).count();
        assert_eq!(shed, 10, "shed spans are always kept");
        // 25 sampled ids (0,4,..96), of which ids 39,79 are... none: shed ids
        // are 9,19,..99 — disjoint from the 1-in-4 sample — so 25 done spans.
        assert_eq!(done, 25, "1-in-4 sampling keeps 25 of 100");
    }

    #[test]
    fn tracer_sample_one_is_exactly_once() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 1024 });
        for _ in 0..50 {
            let tag = t.begin();
            t.finish(tag, SpanKind::Done);
        }
        assert_eq!(t.drain().len(), 50);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn tracer_ring_bounded() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 8 });
        for _ in 0..20 {
            t.finish(t.begin(), SpanKind::Done);
        }
        assert_eq!(t.drain().len(), 8);
        assert_eq!(t.dropped(), 12);
    }

    #[test]
    fn disabled_tracer_emits_nothing() {
        let t = Tracer::new(TraceConfig::default());
        assert!(!t.enabled());
        assert!(t.begin().is_none());
        t.finish(None, SpanKind::Error);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn chrome_trace_roundtrips_through_json() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 16 });
        let mut tag = t.begin().unwrap();
        tag.set_stage(STAGE_QUEUE, Duration::from_micros(120));
        tag.set_stage_secs(STAGE_EDGE, 3.5e-3);
        tag.set_stage_secs(STAGE_UPLINK, f64::NAN); // ignored
        t.finish(Some(tag), SpanKind::Done);
        let spans = t.drain();
        let doc = chrome_trace(&spans).to_string_pretty();
        let parsed = Json::parse(&doc).expect("chrome trace must be valid JSON");
        match parsed {
            Json::Obj(o) => match o.get("traceEvents") {
                Some(Json::Arr(evs)) => {
                    assert_eq!(evs.len(), 9, "1 request envelope + 8 stage events");
                }
                other => panic!("traceEvents missing: {other:?}"),
            },
            other => panic!("not an object: {other:?}"),
        }
    }

    #[test]
    fn chrome_trace_nests_op_events_inside_stage_windows() {
        let t = Tracer::new(TraceConfig { sample: 1, capacity: 16 });
        let mut tag = t.begin().unwrap();
        tag.set_stage(STAGE_EDGE, Duration::from_micros(100));
        tag.set_stage(STAGE_CLOUD, Duration::from_micros(200));
        let sig: Arc<str> = Arc::from("gemm[4x10]");
        tag.ops.push(StagedOp { stage: STAGE_EDGE, sig: Arc::from("quant_pack[2x64]"), dur_ns: 40_000 });
        tag.ops.push(StagedOp { stage: STAGE_CLOUD, sig: Arc::clone(&sig), dur_ns: 70_000 });
        tag.ops.push(StagedOp { stage: STAGE_CLOUD, sig, dur_ns: 50_000 });
        t.finish(Some(tag), SpanKind::Done);
        let spans = t.drain();
        let doc = chrome_trace(&spans);
        let evs = match &doc {
            Json::Obj(o) => match o.get("traceEvents") {
                Some(Json::Arr(evs)) => evs,
                other => panic!("traceEvents missing: {other:?}"),
            },
            other => panic!("not an object: {other:?}"),
        };
        // the stage/envelope layout is unchanged: op events are additive
        assert_eq!(evs.len(), 9 + 3, "9 base events + 3 op events");
        let get = |e: &Json, k: &str| match e {
            Json::Obj(o) => o.get(k).cloned().unwrap(),
            _ => panic!("event not an object"),
        };
        let num = |j: Json| match j {
            Json::Num(n) => n,
            other => panic!("not a number: {other:?}"),
        };
        let ops: Vec<&Json> =
            evs.iter().filter(|e| get(e, "cat") == Json::Str("op".into())).collect();
        assert_eq!(ops.len(), 3);
        // the two cloud ops lie end-to-end inside the cloud stage window
        let cloud_stage = evs
            .iter()
            .find(|e| get(e, "name") == Json::Str("cloud".into()))
            .expect("cloud stage event");
        let cs = num(get(cloud_stage, "ts"));
        let ce = cs + num(get(cloud_stage, "dur"));
        let c0 = ops[1];
        let c1 = ops[2];
        assert_eq!(num(get(c0, "ts")), cs, "first cloud op starts at the stage start");
        assert_eq!(num(get(c1, "ts")), cs + num(get(c0, "dur")), "ops are laid end-to-end");
        assert!(num(get(c1, "ts")) + num(get(c1, "dur")) <= ce + 1e-9, "ops fit the window");
    }

    #[test]
    fn counter_vec_clamps_out_of_range() {
        let v = CounterVec::new(2);
        v.inc(0);
        v.inc(7); // clamps to last slot
        assert_eq!(v.get(0), 1);
        assert_eq!(v.get(1), 1);
        assert_eq!(v.snapshot(), vec![1, 1]);
    }

    #[test]
    fn gauge_and_counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        c.dec();
        assert_eq!(c.get(), 4);
        let g = Gauge::default();
        g.set(9);
        g.max(3);
        g.max(12);
        assert_eq!(g.get(), 12);
    }
}
