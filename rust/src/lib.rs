//! # Auto-Split
//!
//! A production-grade reproduction of *"Auto-Split: A General Framework of
//! Collaborative Edge-Cloud AI"* (KDD 2021): joint DNN splitting and
//! mixed-precision post-training quantization for collaborative edge-cloud
//! inference, plus a serving runtime that executes the resulting partitions
//! via AOT-compiled XLA (PJRT) artifacts.
//!
//! ## Crate map
//! * [`graph`] — DNN DAG substrate (layers, optimization, liveness, min-cut)
//! * [`zoo`] — the paper's benchmark model graphs (ResNet-18/50, GoogleNet,
//!   ResNeXt-50, MobileNet-v2, MnasNet, YOLOv3 family, Faster-RCNN, LPR)
//! * [`profile`] — deterministic synthetic weights + activation statistics
//! * [`sim`] — SCALE-SIM-style latency simulator (Eyeriss / TPU) + uplinks
//! * [`quant`] — quantizers, distortion, Lagrangian bit allocation, packing
//! * [`splitter`] — the Auto-Split optimizer (Algorithm 1) and all baselines
//! * [`runtime`] — PJRT engine loading HLO-text artifacts
//! * [`coordinator`] — the edge↔cloud serving runtime (request path)
//! * [`report`] — table/figure rendering shared by the benches

pub mod graph;
pub mod profile;
pub mod quant;
pub mod coordinator;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod splitter;
pub mod util;
pub mod zoo;

pub use graph::{Graph, LayerKind, NodeId, Shape};
pub use sim::LatencyModel;
