//! The Auto-Split optimizer (the paper's contribution) and its baselines.
//!
//! Entry point: [`Planner`] — configure once, then [`Planner::plan`] a
//! model. The free functions [`auto_split`] / [`auto_split_solutions`] are
//! thin wrappers kept for call-site brevity.

pub mod accuracy;
pub mod autosplit;
pub mod baselines;
pub mod candidates;
pub mod compression;
pub mod planbank;
pub mod planner;
pub mod solutions;

pub use autosplit::{
    auto_split, auto_split_solutions, evaluate_assignment, AutoSplitConfig, TX_HEADER_BYTES,
};
pub use baselines::BaselineCtx;
pub use candidates::{edge_only_fits, potential_splits, SplitCandidate};
pub use planbank::{
    log_spaced_states, preset_states, BankEntry, BankGrid, NetClass, PlanBank, PlanSpec,
};
pub use planner::Planner;
pub use solutions::{Placement, Solution, SolutionList};
