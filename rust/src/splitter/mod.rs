//! The Auto-Split optimizer (the paper's contribution) and its baselines.

pub mod accuracy;
pub mod autosplit;
pub mod baselines;
pub mod candidates;
pub mod compression;
pub mod solutions;

pub use autosplit::{auto_split, auto_split_solutions, evaluate_assignment, AutoSplitConfig};
pub use baselines::BaselineCtx;
pub use candidates::{edge_only_fits, potential_splits, SplitCandidate};
pub use solutions::{Placement, Solution, SolutionList};
