//! Solution representation: a (split, bit-assignment) pair with its full
//! latency / memory / distortion / accuracy evaluation.

use crate::graph::{Graph, LayerKind, NodeId};

/// Where the model executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    CloudOnly,
    EdgeOnly,
    Split,
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Placement::CloudOnly => "CLOUD-ONLY",
            Placement::EdgeOnly => "EDGE-ONLY",
            Placement::Split => "SPLIT",
        };
        write!(f, "{s}")
    }
}

/// A fully evaluated candidate solution of problem (5).
///
/// `PartialEq` compares every field exactly (f64 bit-for-bit under the
/// usual `==`); the planner's parallel/sequential equivalence tests rely
/// on this to prove bit-identical plans.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    /// Producing method ("auto-split", "qdmp", "neurosurgeon", "u8", …).
    pub method: String,
    pub placement: Placement,
    /// Position in the optimized graph's topo order after which the cut
    /// happens (`None` for Cloud-Only).
    pub split_pos: Option<usize>,
    /// Name of the last edge layer.
    pub split_layer: String,
    /// Paper-style split index: number of weighted (conv/linear) layers on
    /// the edge side (ResNet-50 fc = 53, Table 10).
    pub split_index: usize,
    /// Per-node bit assignments (indexed by node id of the graph the
    /// solution was computed on; 16 = float16 / not quantized).
    pub w_bits: Vec<u8>,
    pub a_bits: Vec<u8>,
    /// Latency breakdown, seconds.
    pub edge_s: f64,
    pub tr_s: f64,
    pub cloud_s: f64,
    /// Quantization distortion on the edge partition (eq. 4 LHS), split
    /// into weight and activation terms (the accuracy proxy weighs them
    /// differently; `distortion()` gives the combined sum).
    pub distortion_w: f64,
    pub distortion_a: f64,
    /// Estimated accuracy drop, percent of the float metric.
    pub acc_drop_pct: f64,
    /// Edge model size (weights), bytes.
    pub edge_model_bytes: usize,
    /// Peak edge activation working set, bytes.
    pub edge_act_ws_bytes: usize,
    /// Bytes crossing the uplink per inference.
    pub tx_bytes: usize,
}

impl Solution {
    pub fn total_latency(&self) -> f64 {
        self.edge_s + self.tr_s + self.cloud_s
    }

    /// Combined distortion (eq. 4 LHS).
    pub fn distortion(&self) -> f64 {
        self.distortion_w + self.distortion_a
    }

    /// Edge memory footprint (weights + activation working set), eq. (3).
    pub fn edge_mem_bytes(&self) -> usize {
        self.edge_model_bytes + self.edge_act_ws_bytes
    }
}

/// Number of weighted layers in the topo prefix `order[..=pos]` — the
/// paper's split-index convention.
pub fn weighted_index(g: &Graph, order: &[NodeId], pos: Option<usize>) -> usize {
    match pos {
        None => 0,
        Some(p) => order[..=p]
            .iter()
            .filter(|&&id| matches!(g.layers[id].kind, LayerKind::Conv { .. } | LayerKind::Linear))
            .count(),
    }
}

/// A list of feasible solutions (Algorithm 1's `S`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolutionList {
    pub solutions: Vec<Solution>,
}

impl SolutionList {
    pub fn push(&mut self, s: Solution) {
        self.solutions.push(s);
    }

    pub fn len(&self) -> usize {
        self.solutions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.solutions.is_empty()
    }

    /// Lowest-latency solution whose accuracy drop is within
    /// `max_drop_pct` (the user threshold `A`). Falls back to the most
    /// accurate solution if nothing qualifies.
    pub fn select(&self, max_drop_pct: f64) -> Option<&Solution> {
        let ok = self
            .solutions
            .iter()
            .filter(|s| s.acc_drop_pct <= max_drop_pct + 1e-9)
            .min_by(|a, b| a.total_latency().partial_cmp(&b.total_latency()).unwrap());
        ok.or_else(|| {
            self.solutions
                .iter()
                .min_by(|a, b| a.acc_drop_pct.partial_cmp(&b.acc_drop_pct).unwrap())
        })
    }

    /// Accuracy/latency Pareto frontier (for the Fig. 5 scatter): solutions
    /// not dominated in (latency, drop).
    pub fn pareto(&self) -> Vec<&Solution> {
        let mut front: Vec<&Solution> = vec![];
        for s in &self.solutions {
            let dominated = self.solutions.iter().any(|o| {
                (o.total_latency() < s.total_latency() - 1e-12
                    && o.acc_drop_pct <= s.acc_drop_pct + 1e-12)
                    || (o.acc_drop_pct < s.acc_drop_pct - 1e-12
                        && o.total_latency() <= s.total_latency() + 1e-12)
            });
            if !dominated {
                front.push(s);
            }
        }
        front.sort_by(|a, b| a.acc_drop_pct.partial_cmp(&b.acc_drop_pct).unwrap());
        front
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sol(method: &str, lat: f64, drop: f64) -> Solution {
        Solution {
            method: method.into(),
            placement: Placement::Split,
            split_pos: Some(1),
            split_layer: "x".into(),
            split_index: 1,
            w_bits: vec![],
            a_bits: vec![],
            edge_s: lat,
            tr_s: 0.0,
            cloud_s: 0.0,
            distortion_w: 0.0,
            distortion_a: 0.0,
            acc_drop_pct: drop,
            edge_model_bytes: 0,
            edge_act_ws_bytes: 0,
            tx_bytes: 0,
        }
    }

    #[test]
    fn select_respects_threshold() {
        let mut l = SolutionList::default();
        l.push(sol("fast-bad", 0.1, 20.0));
        l.push(sol("slow-good", 1.0, 0.1));
        l.push(sol("mid", 0.5, 4.0));
        assert_eq!(l.select(5.0).unwrap().method, "mid");
        assert_eq!(l.select(50.0).unwrap().method, "fast-bad");
        assert_eq!(l.select(0.5).unwrap().method, "slow-good");
    }

    #[test]
    fn select_falls_back_to_most_accurate() {
        let mut l = SolutionList::default();
        l.push(sol("a", 0.1, 20.0));
        l.push(sol("b", 0.2, 10.0));
        assert_eq!(l.select(1.0).unwrap().method, "b");
    }

    #[test]
    fn pareto_filters_dominated() {
        let mut l = SolutionList::default();
        l.push(sol("p1", 0.1, 10.0));
        l.push(sol("p2", 1.0, 1.0));
        l.push(sol("dominated", 1.5, 12.0));
        let f = l.pareto();
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|s| s.method != "dominated"));
    }
}
