//! Offline plan bank: the per-network-state optimal plans, precomputed.
//!
//! Auto-Split (Table 1) plans against **one** fixed uplink, but real edge
//! links swing across orders of magnitude (BLE ↔ 5G). The bank makes the
//! planner's output re-usable at runtime: sweep a grid of network states
//! (bandwidth bins from the `sim::network::Uplink` presets plus log-spaced
//! Mbps bins, × SLO tiers) and record, for every state, which plan wins.
//! The serving side (`coordinator::adaptive`) then hot-swaps between the
//! banked plans as its online link estimate moves across bins.
//!
//! ## How the sweep reuses the planner
//!
//! A candidate plan's latency decomposes as `edge + tr(uplink) + cloud`,
//! and only the transmission term depends on the network state. The
//! planner therefore enumerates the feasible `(split, bits)` candidates
//! **once** (its own candidate-level parallel pool), and the bank re-prices
//! `tr` per state from the candidate's `tx_bytes` — equivalent to
//! re-running the planner per state, at a fraction of the cost. The state
//! sweep itself fans across a scoped thread pool with the same
//! index-claiming + index-ordered-merge pattern as `splitter::Planner`,
//! so a bank is **bit-identical for any worker count** (and therefore
//! byte-identical when serialized — the determinism tests lock this).
//!
//! ## Selection rule per state
//!
//! * no SLO tier (`slo_ms == 0`): fastest candidate within the accuracy
//!   threshold (Remark 4, re-priced at this state's uplink);
//! * SLO tier `t`: the **most accurate** candidate whose predicted
//!   end-to-end latency meets `t` — accuracy is the objective once the
//!   budget is met — falling back to the fastest when nothing meets it.
//!
//! Entries pointing at the same winning candidate are deduplicated by plan
//! identity, so a bank stores each distinct plan once.

use super::solutions::Solution;
use crate::sim::{CalibScales, Uplink};
use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Magic identifying a serialized bank.
pub const BANK_MAGIC: &str = "auto-split-planbank-v1";

/// One network state of the grid: an uplink class the link estimator can
/// land in at runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct NetClass {
    pub name: String,
    pub mbps: f64,
    pub rtt_ms: f64,
}

impl NetClass {
    pub fn new(name: impl Into<String>, mbps: f64, rtt_ms: f64) -> Self {
        NetClass { name: name.into(), mbps, rtt_ms }
    }

    /// The uplink this state prices transmissions against.
    pub fn uplink(&self) -> Uplink {
        Uplink::from_mbps_rtt(self.mbps, self.rtt_ms)
    }
}

/// The paper's §1 network classes, as grid states (Uplink presets).
pub fn preset_states() -> Vec<NetClass> {
    vec![
        NetClass::new("ble", 0.27, 50.0),
        NetClass::new("3g", 3.0, 65.0),
        NetClass::new("wifi", 54.0, 5.0),
        NetClass::new("5g", 100.0, 2.0),
    ]
}

/// `n` log-spaced bandwidth bins over `[lo_mbps, hi_mbps]` (a generic
/// 10 ms RTT), for grids finer than the presets.
pub fn log_spaced_states(lo_mbps: f64, hi_mbps: f64, n: usize) -> Vec<NetClass> {
    assert!(lo_mbps > 0.0 && hi_mbps > lo_mbps && n >= 2);
    (0..n)
        .map(|i| {
            let f = i as f64 / (n - 1) as f64;
            let mbps = lo_mbps * (hi_mbps / lo_mbps).powf(f);
            NetClass::new(format!("{mbps:.2}mbps"), mbps, 10.0)
        })
        .collect()
}

/// The full sweep grid: network states × SLO tiers, plus the accuracy
/// threshold the no-SLO selection honours.
#[derive(Debug, Clone)]
pub struct BankGrid {
    pub states: Vec<NetClass>,
    /// End-to-end latency tiers in ms; `0.0` is the "no SLO" tier.
    pub slo_tiers_ms: Vec<f64>,
    /// Accuracy-drop threshold `A` (percent) for the no-SLO selection.
    pub max_drop_pct: f64,
}

impl Default for BankGrid {
    fn default() -> Self {
        BankGrid { states: preset_states(), slo_tiers_ms: vec![0.0], max_drop_pct: 5.0 }
    }
}

impl BankGrid {
    /// Add `n` log-spaced Mbps bins to the preset states.
    pub fn with_log_bins(mut self, lo_mbps: f64, hi_mbps: f64, n: usize) -> Self {
        self.states.extend(log_spaced_states(lo_mbps, hi_mbps, n));
        self
    }

    pub fn with_tiers(mut self, tiers_ms: &[f64]) -> Self {
        self.slo_tiers_ms = tiers_ms.to_vec();
        self
    }
}

/// One banked plan: the state-independent summary of a `(split, bits)`
/// candidate, plus (optionally) where its runnable artifacts live.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSpec {
    /// Deduplication identity (stable digest of the assignment).
    pub id: String,
    pub method: String,
    pub split_index: usize,
    pub split_layer: String,
    /// Modeled edge compute, seconds (uplink-independent).
    pub edge_s: f64,
    /// Modeled cloud compute, seconds (uplink-independent).
    pub cloud_s: f64,
    /// Wire bytes per inference (payload + protocol headers).
    pub tx_bytes: usize,
    pub acc_drop_pct: f64,
    /// Artifact directory relative to the bank root (`None` for
    /// plan-table-only banks, e.g. straight from the zoo planner).
    pub artifacts: Option<String>,
}

impl PlanSpec {
    /// Predicted end-to-end seconds at a network state: the plan's
    /// compute terms plus its transmission re-priced at this uplink.
    pub fn predict_s(&self, state: &NetClass) -> f64 {
        self.predict_calibrated_s(state, &CalibScales::identity())
    }

    /// [`PlanSpec::predict_s`] with measured-latency calibration
    /// (`sim::calib`): each analytic term is multiplied by its stage's
    /// measured/prior ratio, plus the additive per-request overhead the
    /// analytic model does not price. Identity scales reproduce
    /// `predict_s` bit-exactly (`x * 1.0` and `x + 0.0` are exact).
    pub fn predict_calibrated_s(&self, state: &NetClass, scales: &CalibScales) -> f64 {
        scales.edge * self.edge_s
            + scales.cloud * self.cloud_s
            + scales.uplink * state.uplink().transfer_seconds(self.tx_bytes)
            + scales.extra_s
    }

    /// Summarize a planner [`Solution`] into a bank candidate. The id is a
    /// stable digest of the full assignment, so two solutions with the
    /// same split and bit vectors dedup to one plan.
    pub fn from_solution(s: &Solution) -> PlanSpec {
        let mut h = Fnv::new();
        h.push_bytes(s.method.as_bytes());
        h.push_u64(s.split_pos.map(|p| p as u64 + 1).unwrap_or(0));
        h.push_bytes(&s.w_bits);
        h.push_bytes(&s.a_bits);
        h.push_u64(s.tx_bytes as u64);
        PlanSpec {
            id: format!("p{:016x}", h.finish()),
            method: s.method.clone(),
            split_index: s.split_index,
            split_layer: s.split_layer.clone(),
            edge_s: s.edge_s,
            cloud_s: s.cloud_s,
            tx_bytes: s.tx_bytes,
            acc_drop_pct: s.acc_drop_pct,
            artifacts: None,
        }
    }
}

/// FNV-1a 64, the stable digest behind plan identities.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn push_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn push_u64(&mut self, v: u64) {
        self.push_bytes(&v.to_le_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// One grid cell: at this `(state, SLO tier)`, run this plan.
#[derive(Debug, Clone, PartialEq)]
pub struct BankEntry {
    pub state: NetClass,
    /// SLO tier in ms (`0.0` = no SLO).
    pub slo_ms: f64,
    /// Index into [`PlanBank::plans`].
    pub plan: usize,
    /// Predicted end-to-end seconds of the chosen plan at this state.
    pub predicted_s: f64,
}

/// The serialized, deterministic table of per-state optimal plans.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanBank {
    pub model: String,
    /// Image side of the runnable artifacts (0 for plan-table-only banks).
    pub img: usize,
    /// Deduplicated plans, in first-use order over the entry sweep.
    pub plans: Vec<PlanSpec>,
    /// Grid cells in (tier-major, ascending-mbps) order.
    pub entries: Vec<BankEntry>,
}

/// Pure per-cell selection (see the module docs for the rule).
/// Deterministic: ties break to the lowest candidate index.
fn select_for_state(
    candidates: &[PlanSpec],
    state: &NetClass,
    slo_ms: f64,
    max_drop_pct: f64,
    scales: &CalibScales,
) -> (usize, f64) {
    let predict = |i: usize| candidates[i].predict_calibrated_s(state, scales);
    let accurate: Vec<usize> = (0..candidates.len())
        .filter(|&i| candidates[i].acc_drop_pct <= max_drop_pct + 1e-9)
        .collect();
    let pool: Vec<usize> =
        if accurate.is_empty() { (0..candidates.len()).collect() } else { accurate };
    if slo_ms > 0.0 {
        // most accurate plan that meets the latency budget
        let mut best: Option<usize> = None;
        for &i in &pool {
            if predict(i) * 1e3 <= slo_ms + 1e-9 {
                let better = match best {
                    None => true,
                    Some(b) => candidates[i].acc_drop_pct < candidates[b].acc_drop_pct - 1e-12,
                };
                if better {
                    best = Some(i);
                }
            }
        }
        if let Some(i) = best {
            return (i, predict(i));
        }
        // nothing meets the budget: fall through to fastest
    }
    let mut best = pool[0];
    let mut best_s = predict(best);
    for &i in &pool[1..] {
        let s = predict(i);
        if s < best_s - 1e-15 {
            best = i;
            best_s = s;
        }
    }
    (best, best_s)
}

impl PlanBank {
    /// Sweep the grid over `candidates` and assemble the deduplicated
    /// bank. `threads = 0` uses one worker per available core; any worker
    /// count produces a bit-identical bank (index-ordered merge).
    pub fn generate(
        model: &str,
        candidates: &[PlanSpec],
        grid: &BankGrid,
        threads: usize,
    ) -> PlanBank {
        // identity scales reproduce the analytic prediction bit-exactly,
        // so uncalibrated banks are unchanged by the calibration path
        PlanBank::generate_calibrated(model, candidates, grid, threads, &CalibScales::identity())
    }

    /// [`PlanBank::generate`] with every cell priced by
    /// `predict_calibrated_s` — `bankgen --calib` reprices a bank from a
    /// measured `sim::calib::CalibRecord`.
    pub fn generate_calibrated(
        model: &str,
        candidates: &[PlanSpec],
        grid: &BankGrid,
        threads: usize,
        scales: &CalibScales,
    ) -> PlanBank {
        assert!(!candidates.is_empty(), "bank needs at least one candidate plan");
        assert!(!grid.states.is_empty() && !grid.slo_tiers_ms.is_empty());
        // tier-major, ascending-mbps cell order (the switcher's bin order)
        let mut states = grid.states.clone();
        states.sort_by(|a, b| a.mbps.partial_cmp(&b.mbps).unwrap());
        let cells: Vec<(f64, &NetClass)> = grid
            .slo_tiers_ms
            .iter()
            .flat_map(|&t| states.iter().map(move |s| (t, s)))
            .collect();

        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let workers = if threads == 0 { hw } else { threads }.max(1).min(cells.len());
        let picks: Vec<(usize, f64)> = if workers <= 1 {
            cells
                .iter()
                .map(|(t, s)| select_for_state(candidates, s, *t, grid.max_drop_pct, scales))
                .collect()
        } else {
            let next = AtomicUsize::new(0);
            let slots: Vec<Mutex<(usize, f64)>> =
                cells.iter().map(|_| Mutex::new((0, 0.0))).collect();
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cells.len() {
                            break;
                        }
                        let (t, s) = cells[i];
                        *slots[i].lock().unwrap() =
                            select_for_state(candidates, s, t, grid.max_drop_pct, scales);
                    });
                }
            });
            slots.into_iter().map(|m| m.into_inner().unwrap()).collect()
        };

        // dedup by plan identity, in first-use order
        let mut plans: Vec<PlanSpec> = Vec::new();
        let mut index_of: BTreeMap<String, usize> = BTreeMap::new();
        let mut entries = Vec::with_capacity(cells.len());
        for ((tier, state), (cand, predicted_s)) in cells.into_iter().zip(picks) {
            let spec = &candidates[cand];
            let plan = *index_of.entry(spec.id.clone()).or_insert_with(|| {
                plans.push(spec.clone());
                plans.len() - 1
            });
            entries.push(BankEntry { state: state.clone(), slo_ms: tier, plan, predicted_s });
        }
        PlanBank { model: model.to_string(), img: 0, plans, entries }
    }

    /// Entries of one SLO tier, in ascending-mbps order (the switcher's
    /// bin list). Falls back to the `0.0` tier when the requested tier is
    /// not in the bank.
    pub fn tier_entries(&self, slo_ms: f64) -> Vec<&BankEntry> {
        let of_tier = |t: f64| -> Vec<&BankEntry> {
            self.entries.iter().filter(|e| (e.slo_ms - t).abs() < 1e-9).collect()
        };
        let v = of_tier(slo_ms);
        if v.is_empty() {
            of_tier(0.0)
        } else {
            v
        }
    }

    /// Index of a plan by id.
    pub fn plan_index(&self, id: &str) -> Option<usize> {
        self.plans.iter().position(|p| p.id == id)
    }

    /// Serialize deterministically (same bank ⇒ byte-identical text).
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("bank".to_string(), Json::Str(BANK_MAGIC.to_string()));
        root.insert("model".to_string(), Json::Str(self.model.clone()));
        root.insert("img".to_string(), Json::Num(self.img as f64));
        let plans: Vec<Json> = self
            .plans
            .iter()
            .map(|p| {
                let mut o = BTreeMap::new();
                o.insert("id".to_string(), Json::Str(p.id.clone()));
                o.insert("method".to_string(), Json::Str(p.method.clone()));
                o.insert("split_index".to_string(), Json::Num(p.split_index as f64));
                o.insert("split_layer".to_string(), Json::Str(p.split_layer.clone()));
                o.insert("edge_s".to_string(), Json::Num(p.edge_s));
                o.insert("cloud_s".to_string(), Json::Num(p.cloud_s));
                o.insert("tx_bytes".to_string(), Json::Num(p.tx_bytes as f64));
                o.insert("acc_drop_pct".to_string(), Json::Num(p.acc_drop_pct));
                o.insert(
                    "artifacts".to_string(),
                    match &p.artifacts {
                        Some(a) => Json::Str(a.clone()),
                        None => Json::Null,
                    },
                );
                Json::Obj(o)
            })
            .collect();
        root.insert("plans".to_string(), Json::Arr(plans));
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("state".to_string(), Json::Str(e.state.name.clone()));
                o.insert("mbps".to_string(), Json::Num(e.state.mbps));
                o.insert("rtt_ms".to_string(), Json::Num(e.state.rtt_ms));
                o.insert("slo_ms".to_string(), Json::Num(e.slo_ms));
                o.insert("plan".to_string(), Json::Num(e.plan as f64));
                o.insert("predicted_s".to_string(), Json::Num(e.predicted_s));
                Json::Obj(o)
            })
            .collect();
        root.insert("entries".to_string(), Json::Arr(entries));
        let mut s = Json::Obj(root).to_string_pretty();
        s.push('\n');
        s
    }

    /// Parse a serialized bank.
    pub fn parse(text: &str) -> Result<PlanBank> {
        let j = Json::parse(text).context("plan bank JSON")?;
        let magic = j.get("bank").and_then(|v| v.as_str()).unwrap_or_default();
        anyhow::ensure!(magic == BANK_MAGIC, "bad bank magic {magic:?}");
        let model = j.get("model").and_then(|v| v.as_str()).context("model")?.to_string();
        let img = j.get("img").and_then(|v| v.as_usize()).unwrap_or(0);
        let mut plans = Vec::new();
        for p in j.get("plans").and_then(|v| v.as_arr()).context("plans")? {
            plans.push(PlanSpec {
                id: p.get("id").and_then(|v| v.as_str()).context("plan id")?.to_string(),
                method: p
                    .get("method")
                    .and_then(|v| v.as_str())
                    .unwrap_or("auto-split")
                    .to_string(),
                split_index: p.get("split_index").and_then(|v| v.as_usize()).unwrap_or(0),
                split_layer: p
                    .get("split_layer")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                edge_s: p.get("edge_s").and_then(|v| v.as_f64()).context("edge_s")?,
                cloud_s: p.get("cloud_s").and_then(|v| v.as_f64()).context("cloud_s")?,
                tx_bytes: p.get("tx_bytes").and_then(|v| v.as_usize()).context("tx_bytes")?,
                acc_drop_pct: p.get("acc_drop_pct").and_then(|v| v.as_f64()).unwrap_or(0.0),
                artifacts: p.get("artifacts").and_then(|v| v.as_str()).map(str::to_string),
            });
        }
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(|v| v.as_arr()).context("entries")? {
            let plan = e.get("plan").and_then(|v| v.as_usize()).context("entry plan")?;
            anyhow::ensure!(plan < plans.len(), "entry references plan {plan} of {}", plans.len());
            entries.push(BankEntry {
                state: NetClass {
                    name: e.get("state").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                    mbps: e.get("mbps").and_then(|v| v.as_f64()).context("mbps")?,
                    rtt_ms: e.get("rtt_ms").and_then(|v| v.as_f64()).unwrap_or(10.0),
                },
                slo_ms: e.get("slo_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                plan,
                predicted_s: e.get("predicted_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            });
        }
        anyhow::ensure!(!plans.is_empty() && !entries.is_empty(), "empty bank");
        Ok(PlanBank { model, img, plans, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: &str, edge_ms: f64, tx: usize, drop: f64) -> PlanSpec {
        PlanSpec {
            id: id.into(),
            method: "test".into(),
            split_index: 1,
            split_layer: id.into(),
            edge_s: edge_ms / 1e3,
            cloud_s: 0.0002,
            tx_bytes: tx,
            acc_drop_pct: drop,
            artifacts: None,
        }
    }

    /// The synthetic demo frontier: deeper split ⇒ more edge compute,
    /// fewer bytes on the wire, more accuracy loss.
    fn frontier() -> Vec<PlanSpec> {
        vec![
            cand("b8", 1.0, 16417, 0.3),
            cand("b4", 12.0, 8225, 1.2),
            cand("b2", 30.0, 4129, 2.5),
            cand("b1", 55.0, 2081, 4.5),
        ]
    }

    fn demo_grid() -> BankGrid {
        BankGrid {
            states: vec![
                NetClass::new("ble", 0.27, 50.0),
                NetClass::new("3g", 3.0, 65.0),
                NetClass::new("wifi", 54.0, 5.0),
            ],
            slo_tiers_ms: vec![0.0, 150.0],
            max_drop_pct: 5.0,
        }
    }

    #[test]
    fn each_phase_picks_a_distinct_plan() {
        let bank = PlanBank::generate("demo", &frontier(), &demo_grid(), 1);
        let tier0 = bank.tier_entries(0.0);
        assert_eq!(tier0.len(), 3);
        let ids: Vec<&str> = tier0.iter().map(|e| bank.plans[e.plan].id.as_str()).collect();
        // slow link → deep split, mid link → mid split, fast link → shallow
        assert_eq!(ids, vec!["b1", "b4", "b8"]);
        // entries are ascending in mbps (the switcher's bin order)
        assert!(tier0.windows(2).all(|w| w[0].state.mbps < w[1].state.mbps));
    }

    #[test]
    fn slo_tier_prefers_accuracy_within_budget() {
        let bank = PlanBank::generate("demo", &frontier(), &demo_grid(), 1);
        let tier = bank.tier_entries(150.0);
        assert_eq!(tier.len(), 3);
        let id_at = |mbps: f64| {
            tier.iter()
                .find(|e| (e.state.mbps - mbps).abs() < 1e-9)
                .map(|e| bank.plans[e.plan].id.as_str())
                .unwrap()
        };
        // at 3 Mbps every plan meets 150 ms ⇒ the most accurate one wins
        assert_eq!(id_at(3.0), "b8");
        // at BLE nothing meets 150 ms ⇒ fall back to the fastest
        assert_eq!(id_at(0.27), "b1");
        assert_eq!(id_at(54.0), "b8");
    }

    #[test]
    fn generation_is_parallel_deterministic() {
        let grid = BankGrid::default().with_log_bins(0.1, 200.0, 7).with_tiers(&[0.0, 80.0]);
        let seq = PlanBank::generate("demo", &frontier(), &grid, 1);
        for threads in [2, 3, 8] {
            let par = PlanBank::generate("demo", &frontier(), &grid, threads);
            assert_eq!(seq, par, "threads={threads}");
            assert_eq!(seq.to_json(), par.to_json(), "threads={threads}");
        }
    }

    #[test]
    fn identity_scales_generate_bit_identical_banks() {
        let grid = demo_grid();
        let plain = PlanBank::generate("demo", &frontier(), &grid, 1);
        let cal =
            PlanBank::generate_calibrated("demo", &frontier(), &grid, 1, &CalibScales::identity());
        assert_eq!(plain, cal);
        assert_eq!(plain.to_json(), cal.to_json());
    }

    #[test]
    fn calibrated_scales_reprice_and_reselect() {
        let grid = demo_grid();
        let plain = PlanBank::generate("demo", &frontier(), &grid, 1);
        // measured uplink 10× faster than the prior: byte counts stop
        // mattering, so the cheap-edge shallow split wins even on BLE
        let fast_up = CalibScales { edge: 1.0, uplink: 0.05, cloud: 1.0, extra_s: 0.0 };
        let cal = PlanBank::generate_calibrated("demo", &frontier(), &grid, 1, &fast_up);
        let id_at_ble = |b: &PlanBank| {
            b.tier_entries(0.0)
                .iter()
                .find(|e| e.state.name == "ble")
                .map(|e| b.plans[e.plan].id.clone())
                .unwrap()
        };
        assert_eq!(id_at_ble(&plain), "b1");
        assert_eq!(id_at_ble(&cal), "b8", "repriced uplink changes the BLE winner");
        // additive overhead shifts every no-SLO prediction by the same
        // amount without changing the argmin winner (SLO tiers *can*
        // reselect — a budget that was met may no longer be)
        let extra = CalibScales { edge: 1.0, uplink: 1.0, cloud: 1.0, extra_s: 0.5 };
        let shifted = PlanBank::generate_calibrated("demo", &frontier(), &grid, 1, &extra);
        for (a, b) in plain.entries.iter().zip(&shifted.entries) {
            if a.slo_ms == 0.0 {
                assert_eq!(a.state.name, b.state.name);
                assert!((b.predicted_s - a.predicted_s - 0.5).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let mut bank = PlanBank::generate("demo", &frontier(), &demo_grid(), 2);
        bank.img = 128;
        bank.plans[0].artifacts = Some("plans/b8".into());
        let text = bank.to_json();
        let parsed = PlanBank::parse(&text).unwrap();
        assert_eq!(parsed, bank);
        assert_eq!(parsed.to_json(), text, "serialize ∘ parse is the identity");
    }

    #[test]
    fn dedup_stores_each_plan_once() {
        let bank = PlanBank::generate("demo", &frontier(), &demo_grid(), 1);
        let mut ids: Vec<&str> = bank.plans.iter().map(|p| p.id.as_str()).collect();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "plans must be unique");
        assert!(n < bank.entries.len(), "entries share deduped plans");
        for e in &bank.entries {
            assert!(e.plan < bank.plans.len());
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(PlanBank::parse("{}").is_err());
        assert!(PlanBank::parse("{\"bank\": \"wrong\"}").is_err());
        // entry referencing a missing plan
        let text = r#"{
            "bank": "auto-split-planbank-v1",
            "model": "x", "img": 0,
            "plans": [{"id": "a", "edge_s": 0.1, "cloud_s": 0.1, "tx_bytes": 10,
                       "acc_drop_pct": 0, "artifacts": null}],
            "entries": [{"state": "s", "mbps": 1, "rtt_ms": 10, "slo_ms": 0,
                         "plan": 3, "predicted_s": 0.2}]
        }"#;
        assert!(PlanBank::parse(text).is_err());
    }

    #[test]
    fn plan_ids_from_solutions_are_stable_digests() {
        use crate::splitter::solutions::Placement;
        let s = Solution {
            method: "auto-split".into(),
            placement: Placement::Split,
            split_pos: Some(5),
            split_layer: "conv5".into(),
            split_index: 4,
            w_bits: vec![4, 4, 8],
            a_bits: vec![4, 2, 8],
            edge_s: 0.01,
            tr_s: 0.02,
            cloud_s: 0.001,
            distortion_w: 0.0,
            distortion_a: 0.0,
            acc_drop_pct: 1.0,
            edge_model_bytes: 100,
            edge_act_ws_bytes: 100,
            tx_bytes: 777,
        };
        let a = PlanSpec::from_solution(&s);
        let b = PlanSpec::from_solution(&s);
        assert_eq!(a.id, b.id, "same assignment ⇒ same identity");
        let mut s2 = s.clone();
        s2.a_bits[1] = 4;
        assert_ne!(PlanSpec::from_solution(&s2).id, a.id, "different bits ⇒ new identity");
        assert_eq!(a.tx_bytes, 777);
    }

    #[test]
    fn log_bins_are_geometric() {
        let states = log_spaced_states(0.1, 100.0, 4);
        assert_eq!(states.len(), 4);
        let r01 = states[1].mbps / states[0].mbps;
        let r12 = states[2].mbps / states[1].mbps;
        assert!((r01 - r12).abs() < 1e-9, "geometric spacing");
        assert!((states[0].mbps - 0.1).abs() < 1e-12);
        assert!((states[3].mbps - 100.0).abs() < 1e-9);
    }
}
