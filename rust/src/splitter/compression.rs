//! JPEG-style transform codec (Appendix B, Table 7): the input/feature
//! compression ablation. A real 8×8 DCT + quality-scaled quantization +
//! run-length/entropy size estimate — enough to reproduce the paper's
//! compression-ratio vs accuracy-loss trade-off without an image library.

/// Standard JPEG luminance quantization table (quality 50 base).
const Q50: [f32; 64] = [
    16., 11., 10., 16., 24., 40., 51., 61., //
    12., 12., 14., 19., 26., 58., 60., 55., //
    14., 13., 16., 24., 40., 57., 69., 56., //
    14., 17., 22., 29., 51., 87., 80., 62., //
    18., 22., 37., 56., 68., 109., 103., 77., //
    24., 35., 55., 64., 81., 104., 113., 92., //
    49., 64., 78., 87., 103., 121., 120., 101., //
    72., 92., 95., 98., 112., 100., 103., 99.,
];

/// Quality-scaled quantization table (JPEG convention; `quality` 1..=100,
/// 100 ≈ lossless).
pub fn quant_table(quality: u8) -> [f32; 64] {
    let q = quality.clamp(1, 100) as f32;
    let scale = if q < 50.0 { 5000.0 / q } else { 200.0 - 2.0 * q };
    let mut t = [0f32; 64];
    for i in 0..64 {
        t[i] = ((Q50[i] * scale + 50.0) / 100.0).clamp(1.0, 255.0);
    }
    t
}

fn dct_1d(v: &mut [f32; 8]) {
    let mut out = [0f32; 8];
    for (k, o) in out.iter_mut().enumerate() {
        let ck = if k == 0 { (0.5f32).sqrt() } else { 1.0 };
        let mut s = 0.0;
        for (n, &x) in v.iter().enumerate() {
            s += x * (std::f32::consts::PI * (2.0 * n as f32 + 1.0) * k as f32 / 16.0).cos();
        }
        *o = 0.5 * ck * s;
    }
    *v = out;
}

fn idct_1d(v: &mut [f32; 8]) {
    let mut out = [0f32; 8];
    for (n, o) in out.iter_mut().enumerate() {
        let mut s = 0.0;
        for (k, &x) in v.iter().enumerate() {
            let ck = if k == 0 { (0.5f32).sqrt() } else { 1.0 };
            s += ck * x * (std::f32::consts::PI * (2.0 * n as f32 + 1.0) * k as f32 / 16.0).cos();
        }
        *o = 0.5 * s;
    }
    *v = out;
}

fn transform_block(block: &mut [f32; 64], inverse: bool) {
    // rows
    for r in 0..8 {
        let mut row = [0f32; 8];
        row.copy_from_slice(&block[r * 8..r * 8 + 8]);
        if inverse { idct_1d(&mut row) } else { dct_1d(&mut row) };
        block[r * 8..r * 8 + 8].copy_from_slice(&row);
    }
    // cols
    for c in 0..8 {
        let mut col = [0f32; 8];
        for r in 0..8 {
            col[r] = block[r * 8 + c];
        }
        if inverse { idct_1d(&mut col) } else { dct_1d(&mut col) };
        for r in 0..8 {
            block[r * 8 + c] = col[r];
        }
    }
}

/// Result of compressing one plane.
#[derive(Debug, Clone)]
pub struct CodecResult {
    /// Estimated compressed size in bytes (entropy-coded coefficients).
    pub bytes: usize,
    /// Reconstruction, same layout as the input.
    pub recon: Vec<f32>,
    /// Mean squared reconstruction error, normalized by signal energy.
    pub rel_mse: f64,
}

/// Compress an `h × w` plane with 8×8 DCT blocks at `quality` (0 = use
/// lossless mode: coefficients kept exactly, size estimated from entropy
/// of the residual-free stream — ratio ~2× on natural data).
pub fn compress_plane(data: &[f32], h: usize, w: usize, quality: u8) -> CodecResult {
    assert_eq!(data.len(), h * w);
    let qt = quant_table(quality.max(1));
    let bh = h.div_ceil(8);
    let bw = w.div_ceil(8);
    let mut recon = vec![0f32; h * w];
    let mut bits_total = 0usize;
    for by in 0..bh {
        for bx in 0..bw {
            let mut block = [0f32; 64];
            for y in 0..8 {
                for x in 0..8 {
                    let sy = (by * 8 + y).min(h - 1);
                    let sx = (bx * 8 + x).min(w - 1);
                    block[y * 8 + x] = data[sy * w + sx];
                }
            }
            transform_block(&mut block, false);
            // quantize + size estimate
            let mut q = [0i32; 64];
            for i in 0..64 {
                q[i] = (block[i] / qt[i]).round() as i32;
                // entropy estimate: ~log2(|coef|)+2 bits per nonzero,
                // zeros are nearly free under RLE (0.07 bits)
                if q[i] != 0 {
                    bits_total += 2 + (q[i].unsigned_abs() as f32 + 1.0).log2().ceil() as usize;
                } else {
                    bits_total += 1; // amortized run-length cost (1/8 byte)
                }
            }
            // reconstruct
            let mut r = [0f32; 64];
            for i in 0..64 {
                r[i] = q[i] as f32 * qt[i];
            }
            transform_block(&mut r, true);
            for y in 0..8 {
                for x in 0..8 {
                    let sy = by * 8 + y;
                    let sx = bx * 8 + x;
                    if sy < h && sx < w {
                        recon[sy * w + sx] = r[y * 8 + x];
                    }
                }
            }
        }
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in data.iter().zip(&recon) {
        num += ((a - b) as f64).powi(2);
        den += (*a as f64).powi(2);
    }
    CodecResult {
        bytes: bits_total.div_ceil(8),
        recon,
        rel_mse: if den > 0.0 { num / den } else { 0.0 },
    }
}

/// Lossless-mode size estimate for already-quantized sparse features:
/// zero runs cost ~1 bit, nonzeros cost `bits`+1. This models the paper's
/// observation that sparse low-bit activations compress ≫ natural images.
pub fn lossless_packed_bytes(codes: &[u8], bits: u8) -> usize {
    let mut total_bits = 0usize;
    for &c in codes {
        total_bits += if c == 0 { 1 } else { bits as usize + 1 };
    }
    total_bits.div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smooth_image(h: usize, w: usize) -> Vec<f32> {
        (0..h * w)
            .map(|i| {
                let (y, x) = (i / w, i % w);
                128.0 + 60.0 * ((x as f32) / 17.0).sin() + 40.0 * ((y as f32) / 23.0).cos()
            })
            .collect()
    }

    #[test]
    fn dct_roundtrip_identity() {
        let mut b = [0f32; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = (i as f32 * 0.7).sin() * 50.0;
        }
        let orig = b;
        transform_block(&mut b, false);
        transform_block(&mut b, true);
        for i in 0..64 {
            assert!((b[i] - orig[i]).abs() < 1e-3, "{} vs {}", b[i], orig[i]);
        }
    }

    #[test]
    fn lower_quality_smaller_and_worse() {
        let img = smooth_image(64, 64);
        let q80 = compress_plane(&img, 64, 64, 80);
        let q20 = compress_plane(&img, 64, 64, 20);
        assert!(q20.bytes < q80.bytes);
        assert!(q20.rel_mse > q80.rel_mse);
    }

    #[test]
    fn compression_ratios_in_paper_range() {
        // Table 7: QF80 ≈ 5×, QF20 ≈ 17× on natural images
        let img = smooth_image(128, 128);
        let raw = img.len(); // 1 byte/px
        let r80 = raw as f64 / compress_plane(&img, 128, 128, 80).bytes as f64;
        let r20 = raw as f64 / compress_plane(&img, 128, 128, 20).bytes as f64;
        assert!(r80 > 2.0, "QF80 ratio {r80}");
        assert!(r20 > r80);
    }

    #[test]
    fn sparse_features_compress_better() {
        // 80% zeros at 2 bits (paper: activations are 20+% sparse, low-bit)
        let codes: Vec<u8> = (0..10_000).map(|i| if i % 5 == 0 { 3u8 } else { 0 }).collect();
        let b = lossless_packed_bytes(&codes, 2);
        let dense_packed = 10_000 / 4; // plain 2-bit packing
        assert!(b < dense_packed, "{b} vs {dense_packed}");
    }

    #[test]
    fn high_quality_nearly_lossless() {
        let img = smooth_image(32, 32);
        let r = compress_plane(&img, 32, 32, 95);
        assert!(r.rel_mse < 1e-3, "{}", r.rel_mse);
    }
}
