//! Potential-split identification (§4.1, eq. 6, Fig. 4).
//!
//! Pipeline: optimize the graph (Step 1, done by the caller), take the
//! topological order, and for every prefix cut compute the *minimum*
//! transmission volume (every crossing producer at `b_min`). Keep cuts
//! whose best-case transmission does not exceed the raw-input upload and
//! whose minimum-footprint edge sub-model fits the device memory.

use crate::graph::layer::bits_to_bytes;
use crate::graph::liveness::working_set_uniform;
use crate::graph::{Graph, NodeId};

/// One admissible split point.
#[derive(Debug, Clone)]
pub struct SplitCandidate {
    /// Position in the topo order after which the graph is cut.
    pub pos: usize,
    /// Producers whose activations cross the cut.
    pub cut_nodes: Vec<NodeId>,
    /// Total crossing elements (`Σ s^a` over `cut_nodes`).
    pub cut_elems: usize,
    /// Minimum transmission bytes (at `b_min`).
    pub min_tx_bytes: usize,
    /// Minimum edge footprint: weights + activation working set at `b_min`.
    pub min_mem_bytes: usize,
}

/// Enumerate eq. (6)'s candidate set `P` on an *optimized* graph.
///
/// * `order` — topo order of `g`
/// * `b_min` — lowest bit-width supported by the edge device
/// * `mem_bytes` — edge memory budget `M`
pub fn potential_splits(
    g: &Graph,
    order: &[NodeId],
    b_min: u8,
    mem_bytes: usize,
) -> Vec<SplitCandidate> {
    let t0_bytes = bits_to_bytes(g.input_elems(), 8); // raw 8-bit image upload
    let mut out = Vec::new();
    let mut weight_elems_prefix: usize = 0;

    for pos in 0..order.len() {
        let id = order[pos];
        weight_elems_prefix += g.layers[id].weight_count;
        if pos + 1 == order.len() {
            break; // full prefix = Edge-Only, handled separately
        }
        let mask = g.prefix_mask(order, pos);
        let cut_nodes = g.cut_tensors(&mask);
        let cut_elems: usize = cut_nodes.iter().map(|&u| g.layers[u].act_elems()).sum();
        let min_tx_bytes = bits_to_bytes(cut_elems, b_min);
        // eq. 6 condition 1: T_n ≤ T_0
        if min_tx_bytes > t0_bytes {
            continue;
        }
        // eq. 6 condition 2: minimum-footprint fit
        let w_bytes = bits_to_bytes(weight_elems_prefix, b_min);
        let ws = working_set_uniform(g, order, pos, b_min);
        let min_mem_bytes = w_bytes + ws;
        if min_mem_bytes > mem_bytes {
            continue;
        }
        // Cutting right after the input is the Cloud-Only solution;
        // skip (represented separately) unless it strictly beats raw
        // upload, which cannot happen at the input itself.
        if pos == 0 {
            continue;
        }
        out.push(SplitCandidate { pos, cut_nodes, cut_elems, min_tx_bytes, min_mem_bytes });
    }
    out
}

/// Can the whole model fit on the edge at `b_min` (Edge-Only feasibility)?
pub fn edge_only_fits(g: &Graph, order: &[NodeId], b_min: u8, mem_bytes: usize) -> bool {
    let w = bits_to_bytes(g.total_weights(), b_min);
    let ws = working_set_uniform(g, order, order.len() - 1, b_min);
    w + ws <= mem_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::optimize_for_inference;
    use crate::zoo;

    #[test]
    fn candidates_respect_transmission_filter() {
        let g = zoo::resnet50();
        let opt = optimize_for_inference(&g).graph;
        let order = opt.topo_order();
        let cands = potential_splits(&opt, &order, 2, 4 << 30);
        assert!(!cands.is_empty());
        let t0 = opt.input_elems(); // bytes at 8-bit = elems
        for c in &cands {
            assert!(c.min_tx_bytes <= t0, "cut at {} too big", c.pos);
        }
        // early high-volume layers (56×56×256) must be filtered at b_min=8
        let cands8 = potential_splits(&opt, &order, 8, 4 << 30);
        for c in &cands8 {
            assert!(c.cut_elems <= t0);
        }
        // lower b_min admits more candidates
        assert!(cands.len() >= cands8.len());
    }

    #[test]
    fn memory_filter_prunes() {
        let g = zoo::resnet50();
        let opt = optimize_for_inference(&g).graph;
        let order = opt.topo_order();
        let all = potential_splits(&opt, &order, 2, usize::MAX);
        let tight = potential_splits(&opt, &order, 2, 2 << 20); // 2 MB
        assert!(tight.len() < all.len());
        for c in &tight {
            assert!(c.min_mem_bytes <= 2 << 20);
        }
    }

    #[test]
    fn multi_tensor_cuts_counted() {
        let g = zoo::yolov3();
        let opt = optimize_for_inference(&g).graph;
        let order = opt.topo_order();
        let cands = potential_splits(&opt, &order, 2, 4 << 30);
        // cuts inside the neck cross route tensors too
        assert!(cands.iter().any(|c| c.cut_nodes.len() > 1));
    }

    #[test]
    fn edge_only_feasibility() {
        let g = zoo::mobilenet_v2();
        let opt = optimize_for_inference(&g).graph;
        let order = opt.topo_order();
        assert!(edge_only_fits(&opt, &order, 2, 4 << 30));
        assert!(!edge_only_fits(&opt, &order, 8, 1 << 20));
    }
}
