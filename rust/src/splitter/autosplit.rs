//! Algorithm 1: joint DNN splitting and bit assignment.
//!
//! For each potential split `n ∈ P` (eq. 6) the weight/activation budget
//! grids `{M_k^wgt}`, `{M_k^act}` induced by uniform assignments are
//! solved independently — problem (8) with the Shoham–Gersho Lagrangian
//! allocator, problem (9) with the peak-constrained greedy allocator —
//! and every feasible `(b^w, b^a, n)` combination is evaluated and kept.
//! The caller selects the lowest-latency solution whose estimated accuracy
//! drop is within the user threshold `A` (Remark 4).

use super::accuracy;
use super::planner::Planner;
use super::solutions::{weighted_index, Placement, Solution, SolutionList};
use crate::graph::layer::bits_to_bytes;
use crate::graph::{Graph, NodeId};
use crate::profile::ModelProfile;
use crate::quant::{
    allocate_peak_budget, allocate_sum_budget, DistortionTable, Metric, PeakItem, SumItem,
};
use crate::sim::LatencyModel;
use crate::zoo::Task;

/// Per-crossing-tensor protocol header (Table 5 framing). Defined once in
/// the wire protocol so planned transmission bytes match the serving path
/// byte-for-byte; re-exported here for the optimizer's callers.
pub use crate::coordinator::protocol::TX_HEADER_BYTES;

/// Auto-Split configuration.
#[derive(Debug, Clone)]
pub struct AutoSplitConfig {
    /// Candidate bit-widths supported by the edge device (Remark 1).
    pub bit_set: Vec<u8>,
    /// Edge memory budget `M`, bytes.
    pub edge_mem_bytes: usize,
    /// User accuracy-drop threshold `A`, percent.
    pub max_drop_pct: f64,
    /// Distortion metric (MSE default).
    pub metric: Metric,
}

impl Default for AutoSplitConfig {
    fn default() -> Self {
        AutoSplitConfig {
            bit_set: vec![2, 4, 6, 8],
            // Eyeriss-class edge: weights live in off-chip DRAM; the paper
            // constrains the *deployable* footprint. 32 MB is the HiLens
            // camera-class budget used throughout our experiments.
            edge_mem_bytes: 32 << 20,
            max_drop_pct: 5.0,
            metric: Metric::Mse,
        }
    }
}

/// Precomputed liveness structure for fast working-set evaluation inside
/// the activation allocator: `live[s]` = nodes resident at step `s`.
struct PeakModel {
    live: Vec<Vec<NodeId>>,
}

impl PeakModel {
    fn build(g: &Graph, order: &[NodeId], upto: usize) -> Self {
        let mut pos = vec![usize::MAX; g.len()];
        for (p, &id) in order.iter().enumerate() {
            pos[id] = p;
        }
        let in_prefix = |id: NodeId| pos[id] <= upto;
        let mut last_use = vec![0usize; g.len()];
        for &u in &order[..=upto] {
            let mut last = pos[u];
            let mut crosses = g.succs[u].is_empty();
            for &v in &g.succs[u] {
                if in_prefix(v) {
                    last = last.max(pos[v]);
                } else {
                    crosses = true;
                }
            }
            last_use[u] = if crosses { upto } else { last };
        }
        let mut live = vec![Vec::new(); upto + 1];
        for &u in &order[..=upto] {
            for step in pos[u]..=last_use[u] {
                live[step].push(u);
            }
        }
        PeakModel { live }
    }

    /// Peak bytes given per-node activation bit widths.
    fn peak(&self, g: &Graph, a_bits: &[u8]) -> usize {
        self.live
            .iter()
            .map(|nodes| {
                nodes
                    .iter()
                    .map(|&u| bits_to_bytes(g.layers[u].act_elems(), a_bits[u]))
                    .sum()
            })
            .max()
            .unwrap_or(0)
    }
}

/// Evaluate a concrete `(split, bits)` assignment into a [`Solution`].
///
/// `pos = None` → Cloud-Only; `pos = Some(last)` → Edge-Only.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_assignment(
    method: &str,
    g: &Graph,
    order: &[NodeId],
    pos: Option<usize>,
    w_bits: &[u8],
    a_bits: &[u8],
    lm: &LatencyModel,
    table: &DistortionTable,
    task: Task,
) -> Solution {
    let n = g.len();
    assert_eq!(w_bits.len(), n);
    assert_eq!(a_bits.len(), n);

    let (placement, edge_s, tr_s, cloud_s, tx_bytes, dist_w, dist_a, edge_w_bytes, edge_ws) =
        match pos {
            None => {
                // Cloud-Only: upload the raw 8-bit input.
                let tx = bits_to_bytes(g.input_elems(), 8) + TX_HEADER_BYTES;
                (
                    Placement::CloudOnly,
                    0.0,
                    lm.uplink.transfer_seconds(tx),
                    lm.cloud_all(g),
                    tx,
                    0.0,
                    0.0,
                    0,
                    0,
                )
            }
            Some(p) => {
                let mask = g.prefix_mask(order, p);
                let cut = g.cut_tensors(&mask);
                let edge_only = p + 1 == order.len();
                let mut edge = 0.0;
                let mut dist_w = 0.0;
                let mut dist_a = 0.0;
                let mut w_bytes = 0usize;
                for &id in &order[..=p] {
                    edge += lm.edge_layer(g, id, w_bits[id], a_bits[id]);
                    dist_w += table.weight[id][table.bit_index(w_bits[id])];
                    dist_a += table.act[id][table.bit_index(a_bits[id])];
                    w_bytes += bits_to_bytes(g.layers[id].weight_count, w_bits[id]);
                }
                let tx: usize = if edge_only {
                    0
                } else {
                    cut.iter()
                        .map(|&u| {
                            bits_to_bytes(g.layers[u].act_elems(), a_bits[u]) + TX_HEADER_BYTES
                        })
                        .sum()
                };
                let mut cloud = 0.0;
                for &id in &order[p + 1..] {
                    cloud += lm.cloud_layer(g, id);
                }
                // note: the batch explorer (explore_split) uses the
                // precomputed-context fast path instead of this one
                let pm = PeakModel::build(g, order, p);
                let ws = pm.peak(g, a_bits);
                (
                    if edge_only { Placement::EdgeOnly } else { Placement::Split },
                    edge,
                    lm.uplink.transfer_seconds(tx),
                    cloud,
                    tx,
                    dist_w,
                    dist_a,
                    w_bytes,
                    ws,
                )
            }
        };

    Solution {
        method: method.to_string(),
        placement,
        split_pos: pos,
        split_layer: pos
            .map(|p| g.layers[order[p]].name.clone())
            .unwrap_or_else(|| "input".into()),
        split_index: weighted_index(g, order, pos),
        w_bits: w_bits.to_vec(),
        a_bits: a_bits.to_vec(),
        edge_s,
        tr_s,
        cloud_s,
        distortion_w: dist_w,
        distortion_a: dist_a,
        acc_drop_pct: accuracy::drop_pct_split(dist_w, dist_a, task),
        edge_model_bytes: edge_w_bytes,
        edge_act_ws_bytes: edge_ws,
        tx_bytes,
    }
}

/// Run Algorithm 1 on an **optimized** graph and return the full feasible
/// solution list `S` (Cloud-Only always included).
///
/// Thin wrapper over [`Planner`], which owns candidate enumeration and the
/// (parallel) per-candidate grid search. Plans are bit-identical whatever
/// the worker count — see `Planner` for the determinism argument.
pub fn auto_split_solutions(
    g: &Graph,
    profile: &ModelProfile,
    lm: &LatencyModel,
    task: Task,
    cfg: &AutoSplitConfig,
) -> SolutionList {
    Planner::new(cfg.clone()).solutions(g, profile, lm, task)
}

/// Per-layer edge-latency table over the candidate bit grid, built
/// **once per planner run** and shared read-only across all split
/// candidates (it used to be recomputed lazily inside every candidate —
/// `O(candidates × layers × bits²)` latency-model evaluations instead of
/// `O(layers × bits²)`).
///
/// Values are exactly `lm.edge_layer(g, id, bits[wk], bits[ak])`, so a
/// memoized plan is bit-identical to the unmemoized reference path.
#[derive(Debug, Clone)]
pub(crate) struct EdgeLatMemo {
    nb: usize,
    lat: Vec<f64>,
}

impl EdgeLatMemo {
    pub(crate) fn build(g: &Graph, bits: &[u8], lm: &LatencyModel) -> Self {
        let nb = bits.len();
        let mut lat = vec![0.0f64; g.len() * nb * nb];
        for id in 0..g.len() {
            for (wk, &wb) in bits.iter().enumerate() {
                for (ak, &ab) in bits.iter().enumerate() {
                    lat[(id * nb + wk) * nb + ak] = lm.edge_layer(g, id, wb, ab);
                }
            }
        }
        EdgeLatMemo { nb, lat }
    }

    #[inline]
    fn get(&self, id: usize, wk: usize, ak: usize) -> f64 {
        self.lat[(id * self.nb + wk) * self.nb + ak]
    }
}

/// Extend the distortion table with a 16-bit (zero-distortion) column so
/// float assignments can be evaluated with the same machinery.
pub fn table_with16(t: &DistortionTable) -> DistortionTable {
    let mut t2 = t.clone();
    if !t2.bits.contains(&16) {
        t2.bits.push(16);
        for row in &mut t2.weight {
            row.push(0.0);
        }
        for row in &mut t2.act {
            row.push(0.0);
        }
    }
    t2
}

/// Grid-search the budget pairs of one split position and return every
/// feasible evaluated assignment, in deterministic grid order.
///
/// This is the per-candidate unit of work the [`Planner`] fans out across
/// worker threads: it reads only shared immutable inputs and returns its
/// own result vector, so candidate-level parallelism cannot reorder or
/// perturb anything inside a candidate.
#[allow(clippy::too_many_arguments)]
pub(crate) fn explore_split(
    g: &Graph,
    order: &[NodeId],
    pos: usize,
    table: &DistortionTable,
    lm: &LatencyModel,
    task: Task,
    cfg: &AutoSplitConfig,
    memo: Option<&EdgeLatMemo>,
) -> Vec<Solution> {
    let mut out = Vec::new();
    let bits = &cfg.bit_set;
    let prefix: Vec<NodeId> = order[..=pos].to_vec();

    // Problem (8) items: weighted layers only.
    let w_ids: Vec<NodeId> = prefix
        .iter()
        .copied()
        .filter(|&id| g.layers[id].weight_count > 0)
        .collect();
    let w_items: Vec<SumItem> = w_ids
        .iter()
        .map(|&id| SumItem { elems: g.layers[id].weight_count, dist: table.weight[id].clone() })
        .collect();

    // Problem (9) items: all prefix activations.
    let a_items: Vec<PeakItem> = prefix
        .iter()
        .map(|&id| PeakItem { elems: g.layers[id].act_elems(), dist: table.act[id].clone() })
        .collect();
    let pm = PeakModel::build(g, order, pos);

    // Budget grids induced by uniform assignments (Algorithm 1).
    let w_elems: usize = w_ids.iter().map(|&id| g.layers[id].weight_count).sum();
    let mut w_allocs = Vec::new();
    for &b in bits {
        let budget_bits = w_elems as u128 * b as u128;
        if let Some(a) = allocate_sum_budget(&w_items, bits, budget_bits) {
            let bytes = (a.total_bits as usize).div_ceil(8);
            w_allocs.push((bytes, a));
        }
    }
    let mut a_allocs = Vec::new();
    for &b in bits {
        let uniform = vec![b; g.len()];
        let budget = pm.peak(g, &uniform);
        let peak_fn = |bw: &[u8]| {
            // bw is indexed like a_items (= prefix order); expand to node ids
            let mut full = vec![8u8; g.len()];
            for (k, &id) in prefix.iter().enumerate() {
                full[id] = bw[k];
            }
            pm.peak(g, &full)
        };
        if let Some(a) = allocate_peak_budget(&a_items, bits, budget, peak_fn) {
            a_allocs.push((budget, a));
        }
    }

    // Combine pairs; for each combination additionally sweep the bit-width
    // of the *transmitted* (cut) tensors across the candidate set — the
    // `b^a_n` term of objective (5a) that makes early splits viable
    // (Fig. 3: "when quantized to 4-bits, the transmission cost becomes
    // lowest ... the new optimal split point"; Fig. 7's T dimension).
    let mask = g.prefix_mask(order, pos);
    let cut_nodes = g.cut_tensors(&mask);
    let edge_only = pos + 1 == order.len();
    // §Perf: everything that does not depend on the bit assignment is
    // hoisted out of the (w_alloc × a_alloc × T) loop — the cloud suffix
    // sum, the liveness structure (PeakModel), per-layer edge-latency
    // rows per candidate bit-width, and the split metadata.
    let cloud_suffix: f64 = order[pos + 1..].iter().map(|&id| lm.cloud_layer(g, id)).sum();
    let split_layer = g.layers[order[pos]].name.clone();
    let split_index = super::solutions::weighted_index(g, order, Some(pos));
    // edge_lat[k][id]: latency of layer id at (bits[k] weights, bits[k] acts)
    // is NOT separable; but L^edge(w,a) only enters via max(comp, mem).
    // With a cross-candidate memo (the default Planner path) lookups are
    // free here; the lazy per-candidate cache remains as the memo-less
    // reference path so equivalence is testable.
    let nb = bits.len();
    let mut edge_lat = vec![f64::NAN; if memo.is_some() { 0 } else { g.len() * nb * nb }];
    let mut lat_of = |id: usize, wk: usize, ak: usize| -> f64 {
        if let Some(m) = memo {
            return m.get(id, wk, ak);
        }
        let key = (id * nb + wk) * nb + ak;
        if edge_lat[key].is_nan() {
            edge_lat[key] = lm.edge_layer(g, id, bits[wk], bits[ak]);
        }
        edge_lat[key]
    };
    let bit_idx: Vec<usize> = bits.iter().map(|&b| table.bit_index(b)).collect();

    let mut seen: std::collections::HashSet<(usize, usize, u8)> = Default::default();
    for (wi, (w_bytes, wa)) in w_allocs.iter().enumerate() {
        for (ai, (a_bytes, aa)) in a_allocs.iter().enumerate() {
            if w_bytes + a_bytes > cfg.edge_mem_bytes {
                continue;
            }
            // map node id -> choice index (within prefix)
            let mut w_choice = vec![usize::MAX; g.len()];
            for (k, &id) in w_ids.iter().enumerate() {
                w_choice[id] = wa.choice[k];
            }
            let mut a_choice = vec![usize::MAX; g.len()];
            for (k, &id) in prefix.iter().enumerate() {
                a_choice[id] = aa.choice[k];
            }
            for (tk, &tb) in bits.iter().enumerate() {
                if !seen.insert((wi, ai, tb)) {
                    continue;
                }
                let mut w_bits_v = vec![8u8; g.len()];
                let mut a_bits_v = vec![8u8; g.len()];
                let mut edge = 0.0;
                let mut dist_w = 0.0;
                let mut dist_a = 0.0;
                let mut w_bytes_real = 0usize;
                let default_k = bits.iter().position(|&b| b == 8).unwrap_or(nb - 1);
                for &id in &prefix {
                    let wk = if w_choice[id] != usize::MAX { w_choice[id] } else { default_k };
                    let mut ak = if a_choice[id] != usize::MAX { a_choice[id] } else { default_k };
                    if !edge_only && cut_nodes.contains(&id) {
                        ak = tk;
                    }
                    w_bits_v[id] = bits[wk];
                    a_bits_v[id] = bits[ak];
                    edge += lat_of(id, wk, ak);
                    dist_w += table.weight[id][bit_idx[wk]];
                    dist_a += table.act[id][bit_idx[ak]];
                    w_bytes_real += bits_to_bytes(g.layers[id].weight_count, bits[wk]);
                }
                let tx: usize = if edge_only {
                    0
                } else {
                    cut_nodes
                        .iter()
                        .map(|&u| bits_to_bytes(g.layers[u].act_elems(), tb) + TX_HEADER_BYTES)
                        .sum()
                };
                let ws = pm.peak(g, &a_bits_v);
                if w_bytes_real + ws > cfg.edge_mem_bytes {
                    continue;
                }
                out.push(Solution {
                    method: "auto-split".into(),
                    placement: if edge_only { Placement::EdgeOnly } else { Placement::Split },
                    split_pos: Some(pos),
                    split_layer: split_layer.clone(),
                    split_index,
                    w_bits: w_bits_v,
                    a_bits: a_bits_v,
                    edge_s: edge,
                    tr_s: lm.uplink.transfer_seconds(tx),
                    cloud_s: cloud_suffix,
                    distortion_w: dist_w,
                    distortion_a: dist_a,
                    acc_drop_pct: accuracy::drop_pct_split(dist_w, dist_a, task),
                    edge_model_bytes: w_bytes_real,
                    edge_act_ws_bytes: ws,
                    tx_bytes: tx,
                });
            }
        }
    }
    out
}

/// End-to-end entry: optimize → enumerate → select under the threshold.
/// Returns (full list, selected solution).
///
/// Thin wrapper over [`Planner::plan`] with the default (parallel) worker
/// pool; use [`Planner`] directly to control the thread count.
pub fn auto_split(
    g: &Graph,
    profile: &ModelProfile,
    lm: &LatencyModel,
    task: Task,
    cfg: &AutoSplitConfig,
) -> (SolutionList, Solution) {
    Planner::new(cfg.clone()).plan(g, profile, lm, task)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::optimize_for_inference;
    use crate::zoo;

    fn run(gname: &str, mem_mb: usize, drop: f64) -> (SolutionList, Solution) {
        let (g, task) = zoo::by_name(gname).unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let cfg = AutoSplitConfig {
            edge_mem_bytes: mem_mb << 20,
            max_drop_pct: drop,
            ..Default::default()
        };
        auto_split(&opt, &profile, &lm, task, &cfg)
    }

    #[test]
    fn remark5_never_worse_than_cloud_only() {
        for m in ["resnet18", "googlenet", "mobilenet_v2"] {
            let (list, sel) = run(m, 32, 5.0);
            let cloud = list
                .solutions
                .iter()
                .find(|s| s.placement == Placement::CloudOnly)
                .unwrap();
            assert!(
                sel.total_latency() <= cloud.total_latency() + 1e-9,
                "{m}: selected {} vs cloud {}",
                sel.total_latency(),
                cloud.total_latency()
            );
        }
    }

    #[test]
    fn solutions_respect_memory() {
        let (list, _) = run("resnet18", 8, 5.0);
        for s in &list.solutions {
            if s.placement != Placement::CloudOnly {
                assert!(s.edge_mem_bytes() <= 8 << 20);
            }
        }
    }

    #[test]
    fn tighter_threshold_never_faster() {
        let (list, _) = run("resnet50", 32, 0.0);
        let strict = list.select(0.5).unwrap().total_latency();
        let loose = list.select(10.0).unwrap().total_latency();
        assert!(loose <= strict + 1e-9);
    }

    #[test]
    fn split_beats_cloud_only_at_3mbps() {
        // At 3 Mbps uploading a 224×224 image costs ~0.4 s; a deep split
        // point transmits far less. Auto-Split must find a faster option.
        let (list, sel) = run("resnet50", 32, 5.0);
        assert!(list.len() > 1, "should find split candidates");
        let cloud = list
            .solutions
            .iter()
            .find(|s| s.placement == Placement::CloudOnly)
            .unwrap();
        assert!(sel.total_latency() < cloud.total_latency());
    }

    #[test]
    fn mobilenet_avoids_cloud_only() {
        // paper Fig. 6: MobileNet-v2 / MnasNet run mostly on the edge
        // (EDGE-ONLY in the paper; our simulator sometimes finds a deep
        // SPLIT with 2-bit transmission that is even faster). The
        // essential behaviour: the raw-upload CLOUD-ONLY path loses, and
        // an EDGE-ONLY solution exists in the feasible list.
        let (list, sel) = run("mobilenet_v2", 32, 5.0);
        assert_ne!(sel.placement, Placement::CloudOnly, "{sel:?}");
        assert!(list
            .solutions
            .iter()
            .any(|s| s.placement == Placement::EdgeOnly && s.acc_drop_pct <= 5.0));
    }

    #[test]
    fn evaluate_cloud_only_has_no_edge_cost() {
        let (g, task) = zoo::by_name("resnet18").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let order = opt.topo_order();
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let t = DistortionTable::build(&opt, &profile, &[2, 4, 6, 8, 16], Metric::Mse);
        let bits = vec![16u8; opt.len()];
        let s = evaluate_assignment("x", &opt, &order, None, &bits, &bits, &lm, &t, task);
        assert_eq!(s.edge_s, 0.0);
        assert_eq!(s.edge_model_bytes, 0);
        assert!(s.tr_s > 0.0 && s.cloud_s > 0.0);
    }
}
