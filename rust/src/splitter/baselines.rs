//! The paper's comparison baselines (§5.3, Fig. 3/6, Table 2):
//!
//! * **Neurosurgeon** [31] — chain-only split of the topologically sorted
//!   float model; the sort discards DAG structure, so its objective only
//!   sees the single activation at the cut position and can miss crossing
//!   tensors (evaluated honestly afterwards).
//! * **DADS** [27] — min-cut split of the **un-optimized** float graph.
//! * **QDMP** [58] — min-cut split of the **optimized** float graph
//!   (requires the full model on the edge; `QDMP_E` keeps only the edge
//!   partition, same split).
//! * **QDMP_E + U4** — QDMP's split with uniform 4-bit edge quantization.
//! * **U2/U4/U6/U8** — uniform-precision Edge-Only.
//! * **CLOUD16** — Cloud-Only at FP16.

use super::autosplit::{evaluate_assignment, table_with16};
use super::solutions::Solution;
use crate::graph::{min_cut_split, optimize_for_inference, Graph, NodeId};
use crate::profile::ModelProfile;
use crate::quant::{DistortionTable, Metric};
use crate::sim::LatencyModel;
use crate::zoo::Task;

/// Shared evaluation context for baselines on one model.
pub struct BaselineCtx<'a> {
    /// Optimized inference graph.
    pub g: &'a Graph,
    pub order: Vec<NodeId>,
    pub table: DistortionTable,
    pub lm: &'a LatencyModel,
    pub task: Task,
}

impl<'a> BaselineCtx<'a> {
    pub fn new(g: &'a Graph, profile: &ModelProfile, lm: &'a LatencyModel, task: Task) -> Self {
        let order = g.topo_order();
        let table =
            table_with16(&DistortionTable::build(g, profile, &[2, 4, 6, 8], Metric::Mse));
        BaselineCtx { g, order, table, lm, task }
    }

    fn uniform(&self, bits: u8) -> Vec<u8> {
        vec![bits; self.g.len()]
    }

    /// CLOUD16: upload the input, run everything on the cloud.
    pub fn cloud_only(&self) -> Solution {
        let b = self.uniform(16);
        evaluate_assignment(
            "cloud16", self.g, &self.order, None, &b, &b, self.lm, &self.table, self.task,
        )
    }

    /// Uniform b-bit Edge-Only (U2/U4/U6/U8).
    pub fn uniform_edge_only(&self, bits: u8) -> Solution {
        let b = self.uniform(bits);
        evaluate_assignment(
            &format!("u{bits}"),
            self.g,
            &self.order,
            Some(self.order.len() - 1),
            &b,
            &b,
            self.lm,
            &self.table,
            self.task,
        )
    }

    /// Per-node latency vectors for the min-cut constructions (float16).
    fn latency_vectors(&self, g: &Graph) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let n = g.len();
        let mut le = vec![0.0; n];
        let mut lc = vec![0.0; n];
        let mut lt = vec![0.0; n];
        for i in 0..n {
            le[i] = self.lm.edge_layer(g, i, 16, 16);
            lc[i] = self.lm.cloud_layer(g, i);
            lt[i] = self.lm.transmission(g.layers[i].act_elems(), 16);
        }
        // the input's "transmission" is the raw upload (8-bit pixels)
        lt[0] = self.lm.transmission(g.input_elems(), 8);
        (le, lc, lt)
    }

    /// Map a min-cut edge-side mask to the last topo position of the edge
    /// side (our evaluation splits on topo prefixes; min-cut results on
    /// these DAGs are prefix-shaped because of the closure constraint).
    fn mask_to_pos(&self, edge_side: &[bool]) -> Option<usize> {
        let mut pos = None;
        for (p, &id) in self.order.iter().enumerate() {
            if edge_side[id] {
                pos = Some(p);
            }
        }
        // a lone input node = Cloud-Only
        match pos {
            Some(0) | None => None,
            p => p,
        }
    }

    /// QDMP [58]: min-cut on the optimized float graph.
    /// Returns (solution, requires_full_model_on_edge=true).
    pub fn qdmp(&self) -> Solution {
        let (le, lc, lt) = self.latency_vectors(self.g);
        let cut = min_cut_split(self.g, &le, &lc, &lt);
        let pos = self.mask_to_pos(&cut.edge_side);
        let b = self.uniform(16);
        let mut s = evaluate_assignment(
            "qdmp", self.g, &self.order, pos, &b, &b, self.lm, &self.table, self.task,
        );
        // QDMP stores the ENTIRE model on the edge device (dynamic
        // re-splitting), not just the edge partition.
        s.edge_model_bytes = self.g.model_bytes(16);
        s
    }

    /// QDMP_E: same split, but only the edge partition is stored.
    pub fn qdmp_e(&self) -> Solution {
        let mut s = self.qdmp();
        s.method = "qdmp_e".into();
        if let Some(p) = s.split_pos {
            s.edge_model_bytes = self.order[..=p]
                .iter()
                .map(|&id| self.g.layers[id].weight_bytes(16))
                .sum();
        } else {
            s.edge_model_bytes = 0;
        }
        s
    }

    /// QDMP_E + U4: QDMP's split with a uniform 4-bit edge partition
    /// (§5.4's strongest "quantize QDMP afterwards" baseline).
    pub fn qdmp_e_u4(&self) -> Solution {
        let base = self.qdmp();
        let pos = base.split_pos;
        let mut w = self.uniform(16);
        let mut a = self.uniform(16);
        if let Some(p) = pos {
            for &id in &self.order[..=p] {
                w[id] = 4;
                a[id] = 4;
            }
        }
        evaluate_assignment(
            "qdmp_e+u4", self.g, &self.order, pos, &w, &a, self.lm, &self.table, self.task,
        )
    }

    /// DADS [27]: min-cut on the **un-optimized** graph. BN/activation
    /// nodes inflate apparent transmission volumes, producing the
    /// sub-optimal splits QDMP §5.2 documents. The resulting cut is mapped
    /// through graph optimization and re-evaluated on the optimized graph.
    pub fn dads(&self, unoptimized: &Graph) -> Solution {
        let n = unoptimized.len();
        let mut le = vec![0.0; n];
        let mut lc = vec![0.0; n];
        let mut lt = vec![0.0; n];
        for i in 0..n {
            le[i] = self.lm.edge_layer(unoptimized, i, 16, 16);
            lc[i] = self.lm.cloud_layer(unoptimized, i);
            lt[i] = self.lm.transmission(unoptimized.layers[i].act_elems(), 16);
        }
        lt[0] = self.lm.transmission(unoptimized.input_elems(), 8);
        let cut = min_cut_split(unoptimized, &le, &lc, &lt);
        // map the edge side through BN/act folding onto the optimized graph
        let optres = optimize_for_inference(unoptimized);
        let mut edge_side_opt = vec![false; self.g.len()];
        for (old, &on_edge) in cut.edge_side.iter().enumerate() {
            if on_edge {
                edge_side_opt[optres.mapping[old]] = true;
            }
        }
        let pos = self.mask_to_pos(&edge_side_opt);
        let b = self.uniform(16);
        let mut s = evaluate_assignment(
            "dads", self.g, &self.order, pos, &b, &b, self.lm, &self.table, self.task,
        );
        s.edge_model_bytes = self.g.model_bytes(16); // full model on edge, like QDMP
        s
    }

    /// Neurosurgeon [31]: treats the topo-sorted model as a chain. The
    /// *objective* sees only the activation of the layer at the cut; the
    /// returned solution is then evaluated with the true crossing set
    /// (which is where the DAG information loss hurts).
    pub fn neurosurgeon(&self) -> Solution {
        let g = self.g;
        let mut best_pos: Option<usize> = None;
        let mut best_obj = f64::INFINITY;
        // chain objective: Σ edge(prefix) + tr(single act) + Σ cloud(suffix)
        let mut edge_acc = 0.0;
        let cloud_total: f64 = (0..g.len()).map(|i| self.lm.cloud_layer(g, i)).sum();
        let mut cloud_acc = 0.0;
        // position 0 = cloud-only
        let raw_up = self.lm.transmission(g.input_elems(), 8);
        if raw_up + cloud_total < best_obj {
            best_obj = raw_up + cloud_total;
            best_pos = None;
        }
        for (p, &id) in self.order.iter().enumerate() {
            edge_acc += self.lm.edge_layer(g, id, 16, 16);
            cloud_acc += self.lm.cloud_layer(g, id);
            if p + 1 == self.order.len() {
                // edge-only (no transmission)
                if edge_acc < best_obj {
                    best_obj = edge_acc;
                    best_pos = Some(p);
                }
            } else {
                let tr = self.lm.transmission(g.layers[id].act_elems(), 16);
                let obj = edge_acc + tr + (cloud_total - cloud_acc);
                if obj < best_obj {
                    best_obj = obj;
                    best_pos = Some(p);
                }
            }
        }
        let b = self.uniform(16);
        evaluate_assignment(
            "neurosurgeon",
            g,
            &self.order,
            best_pos,
            &b,
            &b,
            self.lm,
            &self.table,
            self.task,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    fn ctx_for<'a>(
        opt: &'a Graph,
        lm: &'a LatencyModel,
        task: Task,
        profile: &ModelProfile,
    ) -> BaselineCtx<'a> {
        BaselineCtx::new(opt, profile, lm, task)
    }

    #[test]
    fn qdmp_beats_or_ties_neurosurgeon() {
        // QDMP sees the true DAG; Neurosurgeon's chain view cannot win.
        for m in ["resnet50", "googlenet", "yolov3_tiny"] {
            let (g, task) = zoo::by_name(m).unwrap();
            let opt = optimize_for_inference(&g).graph;
            let profile = ModelProfile::synthesize(&opt);
            let lm = LatencyModel::paper_default();
            let ctx = ctx_for(&opt, &lm, task, &profile);
            let q = ctx.qdmp();
            let ns = ctx.neurosurgeon();
            assert!(
                q.total_latency() <= ns.total_latency() + 1e-9,
                "{m}: qdmp {} vs neurosurgeon {}",
                q.total_latency(),
                ns.total_latency()
            );
        }
    }

    #[test]
    fn qdmp_never_worse_than_cloud_or_edge_float() {
        let (g, task) = zoo::by_name("resnet18").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let ctx = ctx_for(&opt, &lm, task, &profile);
        let q = ctx.qdmp();
        let c = ctx.cloud_only();
        assert!(q.total_latency() <= c.total_latency() + 1e-9);
    }

    #[test]
    fn qdmp_e_stores_less_than_qdmp() {
        let (g, task) = zoo::by_name("resnet50").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let ctx = ctx_for(&opt, &lm, task, &profile);
        let q = ctx.qdmp();
        let qe = ctx.qdmp_e();
        assert_eq!(q.split_pos, qe.split_pos);
        assert!(qe.edge_model_bytes <= q.edge_model_bytes);
    }

    #[test]
    fn u4_smaller_but_less_accurate_than_u8() {
        let (g, task) = zoo::by_name("mobilenet_v2").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let ctx = ctx_for(&opt, &lm, task, &profile);
        let u4 = ctx.uniform_edge_only(4);
        let u8b = ctx.uniform_edge_only(8);
        assert!(u4.edge_model_bytes < u8b.edge_model_bytes);
        assert!(u4.acc_drop_pct > u8b.acc_drop_pct);
    }

    #[test]
    fn dads_no_better_than_qdmp() {
        // QDMP cuts the optimized graph; DADS the raw one (§2.2).
        let (g, task) = zoo::by_name("resnet50").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let ctx = ctx_for(&opt, &lm, task, &profile);
        let d = ctx.dads(&g);
        let q = ctx.qdmp();
        assert!(q.total_latency() <= d.total_latency() + 1e-9);
    }

    #[test]
    fn detection_u8_loses_map() {
        // §5.3: uniform 8-bit on detectors loses 10–50% mAP
        let (g, task) = zoo::by_name("yolov3").unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        let lm = LatencyModel::paper_default();
        let ctx = ctx_for(&opt, &lm, task, &profile);
        let u8b = ctx.uniform_edge_only(8);
        assert!(
            (5.0..60.0).contains(&u8b.acc_drop_pct),
            "U8 yolov3 drop {}%",
            u8b.acc_drop_pct
        );
    }
}
