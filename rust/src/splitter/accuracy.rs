//! Distortion → accuracy-drop proxy.
//!
//! The optimizer itself only needs the distortion sums (eq. 4); accuracy
//! enters when *selecting* among feasible solutions (Remark 4: users give
//! an accuracy-drop threshold `A`). The paper measures ImageNet top-1 /
//! COCO mAP on trained checkpoints; without those datasets we map the
//! energy-normalized distortion sums to a drop percentage with a
//! calibrated monotone curve (see DESIGN.md §3):
//!
//! ```text
//!   drop% = 100 · (1 − exp(−(κ_w·D_w + κ_a·D_a)))
//! ```
//!
//! Weights and activations get separate steepness because the paper's
//! evidence requires it: quantizing *all weights* of a detector to 8 bits
//! costs 10–50% mAP (§5.3), while quantizing the *single transmitted
//! activation* to 2–4 bits is benign enough that Auto-Split's split
//! solutions stay inside a 10% threshold (Fig. 5/7 — the entire premise
//! of low-bit transmission). κ values are fitted to the distortion
//! magnitudes our synthetic profiles produce (whole-model sums at U8:
//! D_w ≈ 0.015–0.023, D_a ≈ 0.003–0.004; one activation tensor at 2 bits:
//! D_a ≈ 0.3). Only ordering / threshold behaviour matters to the
//! algorithm.

use crate::zoo::Task;

/// Calibrated steepness (κ_w, κ_a) per task family.
pub fn kappa(task: Task) -> (f64, f64) {
    match task {
        Task::Classification => (0.35, 0.06),
        Task::Detection => (12.6, 0.18),
    }
}

/// Accuracy drop (percent of the float metric) for given weight and
/// activation distortion sums over the edge partition.
pub fn drop_pct_split(d_weights: f64, d_acts: f64, task: Task) -> f64 {
    let (kw, ka) = kappa(task);
    let x = kw * d_weights.max(0.0) + ka * d_acts.max(0.0);
    if x <= 0.0 {
        return 0.0;
    }
    100.0 * (1.0 - (-x).exp())
}

/// Convenience for a combined distortion treated as weight-dominated
/// (back-compat path used by coarse estimates).
pub fn drop_pct(total_distortion: f64, task: Task) -> f64 {
    drop_pct_split(total_distortion, 0.0, task)
}

/// The weight-distortion budget `E_w` implied by a drop threshold `A`
/// with zero activation distortion (eq. 4's translation, Remark 4).
pub fn distortion_budget(max_drop_pct: f64, task: Task) -> f64 {
    if max_drop_pct >= 100.0 {
        return f64::INFINITY;
    }
    let (kw, _) = kappa(task);
    -(1.0 - max_drop_pct / 100.0).ln() / kw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_both_terms() {
        for task in [Task::Classification, Task::Detection] {
            let mut prev = -1.0;
            for d in [0.0, 0.001, 0.01, 0.1, 1.0, 10.0, 100.0] {
                let p = drop_pct_split(d, d, task);
                assert!(p >= prev);
                assert!((0.0..=100.0).contains(&p));
                prev = p;
            }
            assert!(
                drop_pct_split(0.1, 0.5, task) > drop_pct_split(0.1, 0.1, task)
            );
        }
    }

    #[test]
    fn detection_more_sensitive() {
        for d in [0.01, 0.1, 1.0, 5.0] {
            assert!(
                drop_pct_split(d, d, Task::Detection)
                    > drop_pct_split(d, d, Task::Classification)
            );
        }
    }

    #[test]
    fn calibration_matches_paper_bands() {
        // whole-model U8 detection (D_w≈0.023, D_a≈0.004): 10–50% band
        let u8_det = drop_pct_split(0.023, 0.004, Task::Detection);
        assert!((10.0..50.0).contains(&u8_det), "U8 detection drop {u8_det}%");
        // whole-model U4 detection: ≳80% (Fig. 5-right)
        let u4_det = drop_pct_split(7.5, 1.2, Task::Detection);
        assert!(u4_det > 80.0, "U4 detection drop {u4_det}%");
        // one transmitted activation at 2 bits (D_a≈0.3, tiny D_w): benign
        let t2 = drop_pct_split(0.0, 0.3, Task::Detection);
        assert!(t2 < 10.0, "T2 transmission drop {t2}%");
        // whole-model U8 classification: <1.5%
        let u8_cls = drop_pct_split(0.0144, 0.0026, Task::Classification);
        assert!(u8_cls < 1.5, "U8 classification drop {u8_cls}%");
        // whole-model U2 classification: catastrophic
        let u2_cls = drop_pct_split(51.0, 26.0, Task::Classification);
        assert!(u2_cls > 30.0, "U2 classification drop {u2_cls}%");
        // a shallow W8A8 detection prefix (D_w≈0.005) under 10%
        let split_det = drop_pct_split(0.005, 0.001, Task::Detection);
        assert!(split_det < 10.0, "shallow U8 prefix drop {split_det}%");
    }

    #[test]
    fn budget_roundtrips() {
        for task in [Task::Classification, Task::Detection] {
            for a in [0.5, 5.0, 10.0, 50.0] {
                let e = distortion_budget(a, task);
                let back = drop_pct_split(e, 0.0, task);
                assert!((back - a).abs() < 1e-6, "{back} vs {a}");
            }
        }
    }

    #[test]
    fn zero_distortion_zero_drop() {
        assert_eq!(drop_pct_split(0.0, 0.0, Task::Classification), 0.0);
    }
}
