//! The reusable `Planner` API: Algorithm 1 with a hand-rolled scoped
//! thread pool over the per-split-candidate `(b^w, b^a, n)` grids.
//!
//! ## Why candidate-level parallelism
//!
//! Algorithm 1 factorizes per split candidate `n`: each candidate solves
//! its own problems (8)/(9) and sweeps its own `(b^w, b^a, T)` grid over
//! **shared immutable inputs** (graph, distortion table, latency model).
//! Candidates are therefore embarrassingly parallel, and they dominate the
//! planner's wall time (the distortion table is built once up front).
//!
//! ## Determinism
//!
//! Plans are **bit-identical** to the sequential path for any worker
//! count:
//!
//! 1. [`explore_split`] is a pure function of its candidate — it performs
//!    no cross-candidate floating-point accumulation, and the evaluation
//!    order *inside* a candidate is untouched.
//! 2. Workers claim candidate *indices* from an atomic counter and write
//!    each result into the slot of its index; the merge step concatenates
//!    the slots in index order. Scheduling can change which thread runs a
//!    candidate, never where its results land.
//!
//! The `planner_equivalence` integration test locks this property, and
//! the golden-plan fixtures lock the plans themselves.

use super::autosplit::{
    evaluate_assignment, explore_split, table_with16, AutoSplitConfig, EdgeLatMemo,
};
use super::candidates::{edge_only_fits, potential_splits};
use super::solutions::{Solution, SolutionList};
use crate::graph::{Graph, NodeId};
use crate::profile::ModelProfile;
use crate::quant::DistortionTable;
use crate::sim::LatencyModel;
use crate::zoo::Task;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Reusable Auto-Split planner: configuration + worker pool policy.
#[derive(Debug, Clone)]
pub struct Planner {
    cfg: AutoSplitConfig,
    /// Worker threads for the candidate grid; 0 = one per available core.
    threads: usize,
    /// Precompute the per-layer edge-latency table once per run and share
    /// it across candidates (bit-identical results; on by default).
    edge_memo: bool,
}

impl Planner {
    /// Planner with the default pool (one worker per available core).
    pub fn new(cfg: AutoSplitConfig) -> Self {
        Planner { cfg, threads: 0, edge_memo: true }
    }

    /// Single-threaded planner (the reference path for equivalence tests).
    pub fn sequential(cfg: AutoSplitConfig) -> Self {
        Planner { cfg, threads: 1, edge_memo: true }
    }

    /// Override the worker count (0 = one per available core).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Toggle the cross-candidate edge-latency memo (the `false` path
    /// recomputes latencies per candidate — the pre-memo reference,
    /// kept for equivalence tests and the `optimizer_hotpath` bench).
    pub fn with_edge_memo(mut self, on: bool) -> Self {
        self.edge_memo = on;
        self
    }

    /// The active configuration.
    pub fn config(&self) -> &AutoSplitConfig {
        &self.cfg
    }

    /// Effective worker count for `jobs` independent candidates.
    fn worker_count(&self, jobs: usize) -> usize {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let t = if self.threads == 0 { hw } else { self.threads };
        t.max(1).min(jobs.max(1))
    }

    /// Run Algorithm 1: enumerate every feasible `(split, bit-assignment)`
    /// solution (Cloud-Only always included, Remark 3).
    pub fn solutions(
        &self,
        g: &Graph,
        profile: &ModelProfile,
        lm: &LatencyModel,
        task: Task,
    ) -> SolutionList {
        let order = g.topo_order();
        let bits = &self.cfg.bit_set;
        // the profiling pass is layer-parallel with the same pool policy as
        // the candidate grid below (bit-identical to sequential)
        let table = DistortionTable::build_parallel(
            g,
            profile,
            bits,
            self.cfg.metric,
            self.worker_count(g.len()),
        );
        let b_min = bits[0];
        let float_bits = vec![16u8; g.len()]; // for Cloud-Only bookkeeping

        let mut list = SolutionList::default();
        // Cloud-Only is always feasible (Remark 3).
        list.push(evaluate_assignment(
            "auto-split",
            g,
            &order,
            None,
            &float_bits,
            &float_bits,
            lm,
            &table_with16(&table),
            task,
        ));

        // Candidate splits (eq. 6) + Edge-Only if it fits at b_min.
        let mut cand_positions: Vec<usize> =
            potential_splits(g, &order, b_min, self.cfg.edge_mem_bytes)
                .into_iter()
                .map(|c| c.pos)
                .collect();
        if edge_only_fits(g, &order, b_min, self.cfg.edge_mem_bytes) {
            cand_positions.push(order.len() - 1);
        }

        for sols in self.explore_candidates(g, &order, &cand_positions, &table, lm, task) {
            list.solutions.extend(sols);
        }
        list
    }

    /// End-to-end: enumerate, then select the fastest solution within the
    /// accuracy threshold (Remark 4). Returns (full list, selection).
    pub fn plan(
        &self,
        g: &Graph,
        profile: &ModelProfile,
        lm: &LatencyModel,
        task: Task,
    ) -> (SolutionList, Solution) {
        let list = self.solutions(g, profile, lm, task);
        let sel = list
            .select(self.cfg.max_drop_pct)
            .expect("cloud-only always present")
            .clone();
        (list, sel)
    }

    /// Evaluate every candidate's grid, one result vector per candidate,
    /// in candidate order. Work is distributed over a scoped thread pool
    /// (the offline environment has no rayon); see the module docs for the
    /// determinism argument.
    fn explore_candidates(
        &self,
        g: &Graph,
        order: &[NodeId],
        positions: &[usize],
        table: &DistortionTable,
        lm: &LatencyModel,
        task: Task,
    ) -> Vec<Vec<Solution>> {
        let workers = self.worker_count(positions.len());
        let cfg = &self.cfg;
        // The edge-latency memo is built once and shared read-only by
        // every worker; candidates no longer re-derive per-layer edge
        // latencies (see `EdgeLatMemo`).
        let memo = if self.edge_memo {
            Some(EdgeLatMemo::build(g, &cfg.bit_set, lm))
        } else {
            None
        };
        let memo = memo.as_ref();
        if workers <= 1 || positions.len() <= 1 {
            return positions
                .iter()
                .map(|&pos| explore_split(g, order, pos, table, lm, task, cfg, memo))
                .collect();
        }

        // Index-claiming pool: deeper candidates cost more (longer
        // prefixes), so dynamic claiming balances better than chunking.
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Vec<Solution>>> =
            positions.iter().map(|_| Mutex::new(Vec::new())).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= positions.len() {
                        break;
                    }
                    let sols = explore_split(g, order, positions[i], table, lm, task, cfg, memo);
                    *slots[i].lock().unwrap() = sols;
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::optimize_for_inference;
    use crate::zoo;

    fn inputs(model: &str) -> (Graph, ModelProfile, LatencyModel, Task) {
        let (g, task) = zoo::by_name(model).unwrap();
        let opt = optimize_for_inference(&g).graph;
        let profile = ModelProfile::synthesize(&opt);
        (opt, profile, LatencyModel::paper_default(), task)
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let (g, profile, lm, task) = inputs("squeezenet1_0");
        let cfg = AutoSplitConfig::default();
        let seq = Planner::sequential(cfg.clone()).solutions(&g, &profile, &lm, task);
        for threads in [2, 3, 8] {
            let par = Planner::new(cfg.clone())
                .with_threads(threads)
                .solutions(&g, &profile, &lm, task);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn free_function_matches_sequential_reference() {
        // `auto_split` delegates to the default (parallel) planner; compare
        // it against the independent single-threaded path so the wrapper's
        // pool is actually exercised against the reference.
        let (g, profile, lm, task) = inputs("lpr_edge_cnn");
        let cfg = AutoSplitConfig::default();
        let (list_a, sel_a) =
            super::super::autosplit::auto_split(&g, &profile, &lm, task, &cfg);
        let (list_b, sel_b) = Planner::sequential(cfg).plan(&g, &profile, &lm, task);
        assert_eq!(list_a, list_b);
        assert_eq!(sel_a, sel_b);
    }

    #[test]
    fn memoized_matches_unmemoized_bitwise() {
        // the cross-candidate edge-latency memo must not perturb plans:
        // same values, same evaluation order, bit-identical solutions
        let (g, profile, lm, task) = inputs("squeezenet1_0");
        let cfg = AutoSplitConfig::default();
        let with = Planner::new(cfg.clone()).with_threads(2).solutions(&g, &profile, &lm, task);
        let without = Planner::new(cfg)
            .with_threads(2)
            .with_edge_memo(false)
            .solutions(&g, &profile, &lm, task);
        assert_eq!(with, without);
    }

    #[test]
    fn worker_count_clamped() {
        let p = Planner::new(AutoSplitConfig::default()).with_threads(64);
        assert_eq!(p.worker_count(3), 3);
        assert_eq!(p.worker_count(0), 1);
        let s = Planner::sequential(AutoSplitConfig::default());
        assert_eq!(s.worker_count(100), 1);
    }
}
