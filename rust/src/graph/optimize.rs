//! Inference-graph optimizations: batch-norm folding and activation fusion.
//!
//! The paper (§2.2, Fig. 3/4 "Step 1") stresses that splitting must be done
//! on the *optimized* execution graph: DADS cuts the un-optimized graph and
//! can return sub-optimal splits because BN/ReLU nodes create spurious edges
//! with large activations. QDMP and Auto-Split both cut the optimized graph.

use super::dag::{Graph, NodeId};
use super::layer::LayerKind;

/// Result of [`optimize_for_inference`]: the rewritten graph plus the
/// old-node → new-node mapping (folded nodes map to the node that absorbed
/// them).
#[derive(Debug, Clone)]
pub struct OptimizedGraph {
    pub graph: Graph,
    /// `mapping[old_id] = new_id`.
    pub mapping: Vec<NodeId>,
    pub folded_bn: usize,
    pub fused_act: usize,
}

/// Fold batch-norms into their producing conv/linear and fuse standalone
/// activations into their producer, whenever the producer's output has no
/// other consumer. Returns the rewritten graph.
pub fn optimize_for_inference(g: &Graph) -> OptimizedGraph {
    let order = g.topo_order();
    let mut mapping: Vec<Option<NodeId>> = vec![None; g.len()];
    let mut out = Graph { name: g.name.clone(), ..Default::default() };
    let mut folded_bn = 0;
    let mut fused_act = 0;

    for &id in &order {
        let layer = &g.layers[id];
        // Candidate for folding into producer?
        if g.preds[id].len() == 1 {
            let p_old = g.preds[id][0];
            // The producer must feed *only* this node, otherwise other
            // consumers would observe the un-folded tensor.
            if g.succs[p_old].len() == 1 {
                let p_new = mapping[p_old].expect("topo order");
                let target = &out.layers[p_new];
                match layer.kind {
                    LayerKind::BatchNorm
                        if matches!(target.kind, LayerKind::Conv { .. } | LayerKind::Linear)
                            && !target.folded_bn
                            && target.fused_activation.is_none() =>
                    {
                        // w' = w*γ/σ, b' = (b-μ)*γ/σ + β : same weight count,
                        // the BN's own 4C params disappear.
                        out.layers[p_new].folded_bn = true;
                        mapping[id] = Some(p_new);
                        folded_bn += 1;
                        continue;
                    }
                    LayerKind::Activation(act)
                        if matches!(
                            target.kind,
                            LayerKind::Conv { .. }
                                | LayerKind::Linear
                                | LayerKind::Add
                                | LayerKind::Mul
                        ) && target.fused_activation.is_none() =>
                    {
                        out.layers[p_new].fused_activation = Some(act);
                        mapping[id] = Some(p_new);
                        fused_act += 1;
                        continue;
                    }
                    _ => {}
                }
            }
        }
        // Keep the node: remap predecessors.
        let new_preds: Vec<NodeId> = g.preds[id]
            .iter()
            .map(|&p| mapping[p].expect("topo order"))
            .collect();
        let new_id = out.layers.len();
        let mut l = layer.clone();
        l.in_shapes = new_preds.iter().map(|&p| out.layers[p].out_shape).collect();
        out.layers.push(l);
        out.preds.push(new_preds.clone());
        out.succs.push(vec![]);
        for &p in &new_preds {
            out.succs[p].push(new_id);
        }
        mapping[id] = Some(new_id);
    }

    let mapping: Vec<NodeId> = mapping.into_iter().map(|m| m.unwrap()).collect();
    debug_assert!(out.validate().is_ok());
    OptimizedGraph { graph: out, mapping, folded_bn, fused_act }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{ActKind, LayerKind, Shape};

    /// conv -> bn -> relu -> conv -> bn -> relu with a skip add.
    fn sample() -> Graph {
        let mut g = Graph::new("s", Shape::new(3, 16, 16));
        let c1 = g.add("c1", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 8);
        let b1 = g.add("b1", LayerKind::BatchNorm, &[c1], 0);
        let r1 = g.add("r1", LayerKind::Activation(ActKind::Relu), &[b1], 0);
        let c2 = g.add("c2", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[r1], 8);
        let b2 = g.add("b2", LayerKind::BatchNorm, &[c2], 0);
        let a = g.add("add", LayerKind::Add, &[b2, r1], 0);
        g.add("r2", LayerKind::Activation(ActKind::Relu), &[a], 0);
        g
    }

    #[test]
    fn folds_bn_and_fuses_relu() {
        let g = sample();
        let opt = optimize_for_inference(&g);
        // c1+b1+r1 collapse into one node; c2+b2 collapse (b2 feeds add);
        // add+r2 fuse. Result: input, c1*, c2*, add* = 4 nodes.
        assert_eq!(opt.graph.len(), 4, "{}", opt.graph);
        assert_eq!(opt.folded_bn, 2);
        assert_eq!(opt.fused_act, 2);
        assert!(opt.graph.validate().is_ok());
        // The skip edge must now connect the fused c1 node to the add.
        let add_new = opt.mapping[5];
        let c1_new = opt.mapping[1];
        assert!(opt.graph.preds[add_new].contains(&c1_new));
        // r1 mapped onto c1's fused node.
        assert_eq!(opt.mapping[3], c1_new);
    }

    #[test]
    fn bn_not_folded_when_producer_shared() {
        let mut g = Graph::new("shared", Shape::new(3, 8, 8));
        let c = g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 4);
        let b = g.add("bn", LayerKind::BatchNorm, &[c], 0);
        // second consumer of the raw conv output
        let p = g.add("pw", LayerKind::Conv { kernel: 1, stride: 1, pad: 0, groups: 1 }, &[c], 4);
        g.add("add", LayerKind::Add, &[b, p], 0);
        let opt = optimize_for_inference(&g);
        // BN must survive: conv feeds two consumers.
        assert_eq!(opt.graph.len(), g.len());
        assert_eq!(opt.folded_bn, 0);
    }

    #[test]
    fn mapping_is_surjective_onto_new_ids() {
        let g = sample();
        let opt = optimize_for_inference(&g);
        let mut hit = vec![false; opt.graph.len()];
        for &m in &opt.mapping {
            hit[m] = true;
        }
        assert!(hit.into_iter().all(|h| h));
    }

    #[test]
    fn activation_count_preserved_semantically() {
        let g = sample();
        let opt = optimize_for_inference(&g);
        let fused: usize = opt
            .graph
            .layers
            .iter()
            .filter(|l| l.fused_activation.is_some())
            .count();
        assert_eq!(fused, 2);
    }
}
