//! Activation working-set ("read-write memory") analysis.
//!
//! The paper's memory constraint (eq. 3) needs `M^a`, the peak activation
//! memory while executing the edge partition. For chains this is
//! `max_i s^a_i · b^a_i`; for general DAGs an activation must stay resident
//! until its last consumer has executed (Fig. 4's depthwise example), so we
//! compute the true liveness-based peak over the execution order.

use super::dag::{Graph, NodeId};
use super::layer::bits_to_bytes;

/// Peak live activation bytes while executing `order[..=upto]`, with
/// per-node activation bit-widths `bits` (indexed by node id).
///
/// A node's output is live from the step it executes until the last step
/// that consumes it; outputs consumed *outside* the prefix (i.e. tensors
/// that will cross the split) are kept live through the end of the prefix,
/// since they must be held for transmission.
pub fn working_set_bytes(g: &Graph, order: &[NodeId], upto: usize, bits: &[u8]) -> usize {
    assert!(upto < order.len());
    let mut pos = vec![usize::MAX; g.len()];
    for (p, &id) in order.iter().enumerate() {
        pos[id] = p;
    }
    let in_prefix = |id: NodeId| pos[id] <= upto;

    // last_use[u] = last prefix step at which u's output is needed.
    let mut last_use = vec![0usize; g.len()];
    for &u in &order[..=upto] {
        let mut last = pos[u]; // at minimum, live while producing
        let mut crosses = false;
        for &v in &g.succs[u] {
            if in_prefix(v) {
                last = last.max(pos[v]);
            } else {
                crosses = true;
            }
        }
        // graph outputs inside the prefix also persist (they are results)
        if g.succs[u].is_empty() {
            crosses = true;
        }
        last_use[u] = if crosses { upto } else { last };
    }

    let mut peak = 0usize;
    for step in 0..=upto {
        let mut live = 0usize;
        for &u in &order[..=step] {
            if last_use[u] >= step {
                live += bits_to_bytes(g.layers[u].act_elems(), bits[u]);
            }
        }
        peak = peak.max(live);
    }
    peak
}

/// Convenience: uniform bit-width working set for the full prefix ending at
/// `upto` in `order`.
pub fn working_set_uniform(g: &Graph, order: &[NodeId], upto: usize, bit: u8) -> usize {
    let bits = vec![bit; g.len()];
    working_set_bytes(g, order, upto, &bits)
}

/// The paper's simple chain estimate `max_i (s^a_i × b^a_i)` over the
/// prefix — a lower bound on the true working set; exposed for the
/// ablation comparing chain vs DAG memory models.
pub fn chain_estimate_bytes(g: &Graph, order: &[NodeId], upto: usize, bits: &[u8]) -> usize {
    order[..=upto]
        .iter()
        .map(|&u| bits_to_bytes(g.layers[u].act_elems(), bits[u]))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{LayerKind, Shape};

    /// input -> a -> b -> c -> add(a, c): a stays live across b and c.
    fn skip_graph() -> Graph {
        let mut g = Graph::new("skip", Shape::new(1, 4, 4));
        let a = g.add("a", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 2);
        let b = g.add("b", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[a], 2);
        let c = g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[b], 2);
        g.add("add", LayerKind::Add, &[a, c], 0);
        g
    }

    #[test]
    fn skip_connection_extends_liveness() {
        let g = skip_graph();
        let order = g.topo_order();
        let bits = vec![8u8; g.len()];
        // At the step executing c, a (skip), b (just consumed), c are around:
        // live = a + b + c outputs -> but b dies after c executes; the peak
        // during c's step counts a, b (consumed at this step), c.
        let ws = working_set_bytes(&g, &order, 3, &bits);
        let one = g.layers[1].act_bytes(8);
        assert!(ws >= 2 * one, "skip tensor must be counted: {ws} vs {one}");
        // Chain estimate sees only the single largest tensor.
        let chain = chain_estimate_bytes(&g, &order, 3, &bits);
        assert!(chain < ws);
    }

    #[test]
    fn chain_graph_matches_simple_estimate_scale() {
        // pure chain: working set ≈ in + out of the widest layer (≤ 2×max)
        let mut g = Graph::new("chain", Shape::new(1, 8, 8));
        let mut prev = 0;
        for i in 0..4 {
            prev = g.add(
                format!("c{i}"),
                LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 },
                &[prev],
                2,
            );
        }
        let order = g.topo_order();
        let bits = vec![8u8; g.len()];
        let ws = working_set_bytes(&g, &order, 4, &bits);
        let max_single = chain_estimate_bytes(&g, &order, 4, &bits);
        assert!(ws <= 3 * max_single);
        assert!(ws >= max_single);
    }

    #[test]
    fn lower_bits_shrink_working_set() {
        let g = skip_graph();
        let order = g.topo_order();
        let b8 = vec![8u8; g.len()];
        let b4 = vec![4u8; g.len()];
        let w8 = working_set_bytes(&g, &order, 3, &b8);
        let w4 = working_set_bytes(&g, &order, 3, &b4);
        assert!(w4 * 2 <= w8 + g.len()); // rounding slack
    }

    #[test]
    fn prefix_zero_counts_input_only() {
        let g = skip_graph();
        let order = g.topo_order();
        let bits = vec![8u8; g.len()];
        let ws = working_set_bytes(&g, &order, 0, &bits);
        assert_eq!(ws, g.layers[0].act_bytes(8));
    }
}
