//! Max-flow / min-cut machinery for the DADS [27] / QDMP [58] baselines.
//!
//! DADS-style split: partition the DAG into an edge set `S` (containing the
//! input) and a cloud set `T` (containing the outputs) minimizing
//!
//! ```text
//!   Σ_{v∈S} lat_edge(v) + Σ_{v∈T} lat_cloud(v) + Σ_{u∈S with a consumer ∈T} lat_tr(u)
//! ```
//!
//! which reduces to an s-t min-cut on an auxiliary flow network:
//! * `src → v` with capacity `lat_cloud(v)` (cut ⇔ v placed on edge… see below)
//! * `v → snk` with capacity `lat_edge(v)`
//! * per producer `u`: auxiliary node `x_u`, `u → x_u` with capacity
//!   `lat_tr(u)`, and `x_u → w` with capacity ∞ for each consumer `w`
//!   (transmission is paid once even with several crossing consumers)
//! * `w → u` with capacity ∞ for each DNN edge `u → w`, enforcing that the
//!   cloud side is closed under successors (no cloud→edge data flow).
//!
//! Convention: vertices on the `src` side after the cut are the **edge**
//! partition. `src→v` cut (v on sink side) pays `lat_cloud(v)`; `v→snk` cut
//! (v on source side) pays `lat_edge(v)`.

use super::dag::Graph;

const INF: f64 = f64::INFINITY;

/// Dinic max-flow over f64 capacities.
pub struct Dinic {
    n: usize,
    // edge list: to, cap, and the index of the reverse edge
    to: Vec<usize>,
    cap: Vec<f64>,
    head: Vec<Vec<usize>>, // adjacency: indices into the edge list
    level: Vec<i32>,
    iter: Vec<usize>,
}

impl Dinic {
    pub fn new(n: usize) -> Self {
        Dinic {
            n,
            to: vec![],
            cap: vec![],
            head: vec![vec![]; n],
            level: vec![],
            iter: vec![],
        }
    }

    pub fn add_edge(&mut self, u: usize, v: usize, c: f64) {
        debug_assert!(c >= 0.0);
        let e = self.to.len();
        self.to.push(v);
        self.cap.push(c);
        self.head[u].push(e);
        self.to.push(u);
        self.cap.push(0.0);
        self.head[v].push(e + 1);
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level = vec![-1; self.n];
        let mut q = std::collections::VecDeque::new();
        self.level[s] = 0;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in &self.head[u] {
                let v = self.to[e];
                if self.cap[e] > 1e-12 && self.level[v] < 0 {
                    self.level[v] = self.level[u] + 1;
                    q.push_back(v);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, u: usize, t: usize, f: f64) -> f64 {
        if u == t {
            return f;
        }
        while self.iter[u] < self.head[u].len() {
            let e = self.head[u][self.iter[u]];
            let v = self.to[e];
            if self.cap[e] > 1e-12 && self.level[v] == self.level[u] + 1 {
                let d = self.dfs(v, t, f.min(self.cap[e]));
                if d > 1e-12 {
                    self.cap[e] -= d;
                    self.cap[e ^ 1] += d;
                    return d;
                }
            }
            self.iter[u] += 1;
        }
        0.0
    }

    /// Run max-flow; returns the flow value.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        let mut flow = 0.0;
        while self.bfs(s, t) {
            self.iter = vec![0; self.n];
            loop {
                let f = self.dfs(s, t, INF);
                if f <= 1e-12 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }

    /// After `max_flow`, the set of vertices reachable from `s` in the
    /// residual graph (the source side of the min cut).
    pub fn min_cut_source_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        let mut q = std::collections::VecDeque::new();
        seen[s] = true;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in &self.head[u] {
                let v = self.to[e];
                if self.cap[e] > 1e-12 && !seen[v] {
                    seen[v] = true;
                    q.push_back(v);
                }
            }
        }
        seen
    }
}

/// Result of a DADS/QDMP-style min-cut split.
#[derive(Debug, Clone)]
pub struct MinCutSplit {
    /// `true` for nodes assigned to the edge device.
    pub edge_side: Vec<bool>,
    /// Total objective (edge compute + transmission + cloud compute), same
    /// units as the supplied latency vectors.
    pub objective: f64,
}

/// Solve the DADS partition problem on `g` with per-node latencies.
///
/// `lat_edge[v]` / `lat_cloud[v]`: seconds to run node `v` on each device;
/// `lat_tr[u]`: seconds to transmit node `u`'s output activation.
/// The input node (id 0) is pinned to the edge side with `lat_edge[0] = 0`;
/// its transmission cost models the Cloud-Only upload, so a cut directly
/// after the input reproduces the Cloud-Only solution.
pub fn min_cut_split(
    g: &Graph,
    lat_edge: &[f64],
    lat_cloud: &[f64],
    lat_tr: &[f64],
) -> MinCutSplit {
    let n = g.len();
    assert_eq!(lat_edge.len(), n);
    assert_eq!(lat_cloud.len(), n);
    assert_eq!(lat_tr.len(), n);

    // node ids: 0..n = DNN nodes, n..2n = aux transmit nodes, src=2n, snk=2n+1
    let src = 2 * n;
    let snk = 2 * n + 1;
    let mut d = Dinic::new(2 * n + 2);

    for v in 0..n {
        // v on cloud side ⇒ cut src→v paying cloud latency
        let c_cloud = if v == 0 { INF } else { lat_cloud[v] };
        if c_cloud > 0.0 {
            d.add_edge(src, v, c_cloud);
        }
        // v on edge side ⇒ cut v→snk paying edge latency
        if lat_edge[v] > 0.0 {
            d.add_edge(v, snk, lat_edge[v]);
        }
        if !g.succs[v].is_empty() {
            // transmission aux node
            let x = n + v;
            d.add_edge(v, x, lat_tr[v]);
            for &w in &g.succs[v] {
                d.add_edge(x, w, INF);
                // successor-closure: forbid w on edge while v on cloud
                d.add_edge(w, v, INF);
            }
        }
    }

    let objective = d.max_flow(src, snk);
    let side = d.min_cut_source_side(src);
    let edge_side: Vec<bool> = (0..n).map(|v| side[v]).collect();
    MinCutSplit { edge_side, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{LayerKind, Shape};

    fn chain(k: usize) -> Graph {
        let mut g = Graph::new("chain", Shape::new(1, 8, 8));
        let mut prev = 0;
        for i in 0..k {
            prev = g.add(
                format!("c{i}"),
                LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 },
                &[prev],
                1,
            );
        }
        g
    }

    /// Brute-force over all successor-closed partitions (small graphs).
    fn brute(g: &Graph, le: &[f64], lc: &[f64], lt: &[f64]) -> f64 {
        let n = g.len();
        let mut best = f64::INFINITY;
        'outer: for mask in 0..(1u32 << n) {
            if mask & 1 == 0 {
                continue; // input must be on edge
            }
            let on_edge = |v: usize| mask >> v & 1 == 1;
            // closure: consumer on edge ⇒ producer on edge
            for v in 0..n {
                for &w in &g.succs[v] {
                    if on_edge(w) && !on_edge(v) {
                        continue 'outer;
                    }
                }
            }
            let mut cost = 0.0;
            for v in 0..n {
                if on_edge(v) {
                    cost += le[v];
                    if g.succs[v].iter().any(|&w| !on_edge(w)) {
                        cost += lt[v];
                    }
                } else {
                    cost += lc[v];
                }
            }
            best = best.min(cost);
        }
        best
    }

    #[test]
    fn chain_matches_bruteforce() {
        let g = chain(5);
        let n = g.len();
        // deterministic pseudo-random latencies
        let le: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin().abs()).collect();
        let lc: Vec<f64> = (0..n).map(|i| 0.2 + (i as f64 * 1.3).cos().abs() * 0.3).collect();
        let lt: Vec<f64> = (0..n).map(|i| 0.5 + (i as f64 * 2.1).sin().abs() * 2.0).collect();
        let cut = min_cut_split(&g, &le, &lc, &lt);
        let bf = brute(&g, &le, &lc, &lt);
        assert!((cut.objective - bf).abs() < 1e-6, "{} vs {}", cut.objective, bf);
    }

    #[test]
    fn diamond_matches_bruteforce() {
        let mut g = Graph::new("d", Shape::new(1, 4, 4));
        let a = g.add("a", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 2);
        let b = g.add("b", LayerKind::Conv { kernel: 1, stride: 1, pad: 0, groups: 1 }, &[a], 2);
        let c = g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[a], 2);
        g.add("add", LayerKind::Add, &[b, c], 0);
        let n = g.len();
        let le: Vec<f64> = (0..n).map(|i| 0.5 + i as f64 * 0.1).collect();
        let lc: Vec<f64> = (0..n).map(|i| 0.05 + i as f64 * 0.02).collect();
        let lt: Vec<f64> = (0..n).map(|i| (3.0 - i as f64).abs() * 0.4 + 0.1).collect();
        let cut = min_cut_split(&g, &le, &lc, &lt);
        let bf = brute(&g, &le, &lc, &lt);
        assert!((cut.objective - bf).abs() < 1e-6, "{} vs {}", cut.objective, bf);
        // partition must keep input on the edge side
        assert!(cut.edge_side[0]);
    }

    #[test]
    fn all_cloud_when_edge_is_slow() {
        let g = chain(4);
        let n = g.len();
        let le = vec![100.0; n];
        let lc = vec![0.01; n];
        let lt = vec![0.1; n];
        let cut = min_cut_split(&g, &le, &lc, &lt);
        // everything except the pinned input goes to the cloud
        assert!(cut.edge_side[0]);
        assert!(!cut.edge_side[1..].iter().any(|&b| b));
    }

    #[test]
    fn all_edge_when_transmission_is_expensive() {
        let g = chain(4);
        let n = g.len();
        let le = vec![0.01; n];
        let lc = vec![0.01; n];
        let mut lt = vec![1000.0; n];
        // final node has no successors -> no transmission needed
        lt[n - 1] = 0.0;
        let cut = min_cut_split(&g, &le, &lc, &lt);
        assert!(cut.edge_side.iter().all(|&b| b), "{:?}", cut.edge_side);
    }

    #[test]
    fn closure_respected() {
        // y-branch where one branch is cheap on edge, but its consumer is
        // forced cloud-ward; verify no cloud→edge edges in the result.
        let mut g = Graph::new("y", Shape::new(1, 4, 4));
        let a = g.add("a", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 2);
        let b = g.add("b", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[a], 2);
        g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[b], 2);
        let n = g.len();
        let le: Vec<f64> = vec![0.0, 0.1, 5.0, 0.1];
        let lc: Vec<f64> = vec![0.0, 1.0, 0.1, 1.0];
        let lt: Vec<f64> = vec![0.3, 0.2, 0.2, 0.0];
        let cut = min_cut_split(&g, &le, &lc, &lt);
        for v in 0..n {
            for &w in &g.succs[v] {
                assert!(
                    !(cut.edge_side[w] && !cut.edge_side[v]),
                    "cloud node {v} feeds edge node {w}"
                );
            }
        }
    }
}
