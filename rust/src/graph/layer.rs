//! Layer definitions for the DNN DAG substrate.
//!
//! A [`Layer`] carries everything the Auto-Split optimizer needs about one
//! node of the inference graph: its kind (conv / linear / …), tensor shapes,
//! parameter count (`s^w` in the paper), output activation size (`s^a`),
//! and MAC count (used by the latency simulator).



/// Tensor shape in CHW order (batch dimension is implicit and equals 1 for
/// the latency analysis, matching the paper's single-stream edge setting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub const fn new(c: usize, h: usize, w: usize) -> Self {
        Shape { c, h, w }
    }

    /// 1-D shape helper (e.g. the output of a fully-connected layer).
    pub const fn vec(c: usize) -> Self {
        Shape { c, h: 1, w: 1 }
    }

    /// Number of elements (the paper's `s^a_i` is expressed in elements and
    /// multiplied by the bit-width when converted to bytes).
    pub const fn volume(&self) -> usize {
        self.c * self.h * self.w
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.c, self.h, self.w)
    }
}

/// Supported activation functions (fused into producers by
/// [`crate::graph::optimize::optimize_for_inference`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    Relu,
    Relu6,
    LeakyRelu,
    Sigmoid,
    /// h-swish / swish family (MobileNet-v3, MnasNet SE blocks).
    Swish,
}

/// Pooling flavours.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
    /// Global average pool: output is `(C,1,1)` regardless of kernel.
    GlobalAvg,
}

/// The operator taxonomy. It covers every layer used by the paper's
/// benchmark zoo (ResNet/ResNeXt bottlenecks, GoogleNet inception modules,
/// MobileNet/MnasNet inverted residuals with squeeze-excite, YOLO darknet
/// blocks + upsample/concat routes, Faster-RCNN FPN laterals).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Graph input (raw image). `s^w = 0`.
    Input,
    /// 2-D convolution. `groups > 1` expresses grouped / depthwise conv
    /// (depthwise when `groups == c_in`).
    Conv {
        kernel: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    },
    /// Fully connected.
    Linear,
    /// Batch normalization (folded away by graph optimization).
    BatchNorm,
    /// Standalone activation (fused away by graph optimization).
    Activation(ActKind),
    Pool {
        kernel: usize,
        stride: usize,
        kind: PoolKind,
    },
    /// Elementwise residual add (N inputs, same shape).
    Add,
    /// Elementwise multiply (squeeze-excitation gating).
    Mul,
    /// Channel-wise concatenation.
    Concat,
    /// Nearest-neighbour upsample by an integer factor (YOLO routes).
    Upsample { factor: usize },
    /// Reshape / flatten (no compute, no weights).
    Flatten,
    /// Detection / classification head marker (YOLO layer, softmax, …).
    /// Treated as compute-free but *pinned to the cloud side or final*,
    /// because its consumers are post-processing.
    Head,
}

impl LayerKind {
    /// True for operators that carry trainable parameters.
    pub fn has_weights(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv { .. } | LayerKind::Linear | LayerKind::BatchNorm
        )
    }

    /// True for operators the latency simulator maps onto the systolic
    /// array as a GEMM (everything else is vector-unit / data movement).
    pub fn is_gemm(&self) -> bool {
        matches!(self, LayerKind::Conv { .. } | LayerKind::Linear)
    }

    pub fn short_code(&self) -> &'static str {
        match self {
            LayerKind::Input => "I",
            LayerKind::Conv { groups, kernel, .. } => {
                if *groups > 1 {
                    "D"
                } else if *kernel == 1 {
                    "P"
                } else {
                    "C"
                }
            }
            LayerKind::Linear => "L",
            LayerKind::BatchNorm => "BN",
            LayerKind::Activation(_) => "R",
            LayerKind::Pool { kind: PoolKind::GlobalAvg, .. } => "G",
            LayerKind::Pool { .. } => "Pl",
            LayerKind::Add => "+",
            LayerKind::Mul => "*",
            LayerKind::Concat => "||",
            LayerKind::Upsample { .. } => "Up",
            LayerKind::Flatten => "Fl",
            LayerKind::Head => "H",
        }
    }
}

/// One node of the inference DAG.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Shapes of the inputs, in predecessor order.
    pub in_shapes: Vec<Shape>,
    pub out_shape: Shape,
    /// Parameter element count (`s^w_i`). Bias included for conv/linear.
    pub weight_count: usize,
    /// Multiply-accumulate count for one inference.
    pub macs: u64,
    /// Fused activation (set by graph optimization, or at construction).
    pub fused_activation: Option<ActKind>,
    /// Whether a batch-norm has been folded into this layer.
    pub folded_bn: bool,
}

impl Layer {
    /// Output activation element count (`s^a_i`).
    pub fn act_elems(&self) -> usize {
        self.out_shape.volume()
    }

    /// Weight bytes at a given bit-width.
    pub fn weight_bytes(&self, bits: u8) -> usize {
        bits_to_bytes(self.weight_count, bits)
    }

    /// Output activation bytes at a given bit-width.
    pub fn act_bytes(&self, bits: u8) -> usize {
        bits_to_bytes(self.act_elems(), bits)
    }
}

/// `elems` values of `bits` bits each, packed, rounded up to whole bytes.
pub fn bits_to_bytes(elems: usize, bits: u8) -> usize {
    (elems * bits as usize).div_ceil(8)
}

/// Compute the spatial output size of a conv/pool window.
pub fn conv_out_dim(in_dim: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (in_dim + 2 * pad).saturating_sub(kernel) / stride + 1
}

/// Derive output shape, weight count and MACs for `kind` applied to
/// `in_shapes` producing `out_channels` (where applicable).
pub fn infer_layer(
    kind: LayerKind,
    in_shapes: &[Shape],
    out_channels: usize,
) -> (Shape, usize, u64) {
    match kind {
        LayerKind::Input => (in_shapes[0], 0, 0),
        LayerKind::Conv { kernel, stride, pad, groups } => {
            let i = in_shapes[0];
            assert!(i.c % groups == 0, "in channels {} not divisible by groups {}", i.c, groups);
            assert!(out_channels % groups == 0);
            let oh = conv_out_dim(i.h, kernel, stride, pad);
            let ow = conv_out_dim(i.w, kernel, stride, pad);
            let out = Shape::new(out_channels, oh, ow);
            let w = (i.c / groups) * kernel * kernel * out_channels + out_channels;
            let macs = (i.c / groups) as u64
                * (kernel * kernel) as u64
                * out.volume() as u64;
            (out, w, macs)
        }
        LayerKind::Linear => {
            let i = in_shapes[0].volume();
            let out = Shape::vec(out_channels);
            let w = i * out_channels + out_channels;
            (out, w, (i * out_channels) as u64)
        }
        LayerKind::BatchNorm => {
            let i = in_shapes[0];
            // scale, shift, running mean, running var
            (i, 4 * i.c, i.volume() as u64)
        }
        LayerKind::Activation(_) => (in_shapes[0], 0, in_shapes[0].volume() as u64 / 2),
        LayerKind::Pool { kernel, stride, kind } => {
            let i = in_shapes[0];
            match kind {
                PoolKind::GlobalAvg => (Shape::vec(i.c), 0, i.volume() as u64),
                _ => {
                    // stride-1 pools are same-padded; strided pools use
                    // ceil_mode (torchvision GoogleNet/ResNet convention)
                    let dim = |d: usize| {
                        if stride == 1 {
                            conv_out_dim(d, kernel, 1, kernel / 2)
                        } else {
                            (d - kernel).div_ceil(stride) + 1
                        }
                    };
                    let o = Shape::new(i.c, dim(i.h), dim(i.w));
                    (o, 0, (o.volume() * kernel * kernel) as u64)
                }
            }
        }
        LayerKind::Add | LayerKind::Mul => {
            let a = in_shapes[0];
            // Mul supports broadcasting a (C,1,1) gate over (C,H,W).
            let out = in_shapes
                .iter()
                .copied()
                .max_by_key(|s| s.volume())
                .unwrap_or(a);
            (out, 0, out.volume() as u64)
        }
        LayerKind::Concat => {
            let h = in_shapes[0].h;
            let w = in_shapes[0].w;
            let c: usize = in_shapes.iter().map(|s| s.c).sum();
            for s in in_shapes {
                assert_eq!((s.h, s.w), (h, w), "concat spatial mismatch");
            }
            (Shape::new(c, h, w), 0, 0)
        }
        LayerKind::Upsample { factor } => {
            let i = in_shapes[0];
            (Shape::new(i.c, i.h * factor, i.w * factor), 0, 0)
        }
        LayerKind::Flatten => (Shape::vec(in_shapes[0].volume()), 0, 0),
        LayerKind::Head => (in_shapes[0], 0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_weights() {
        // 3x3 s1 p1 conv, 64->128 over 56x56
        let (out, w, macs) = infer_layer(
            LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 },
            &[Shape::new(64, 56, 56)],
            128,
        );
        assert_eq!(out, Shape::new(128, 56, 56));
        assert_eq!(w, 64 * 9 * 128 + 128);
        assert_eq!(macs, 64 * 9 * 128 * 56 * 56);
    }

    #[test]
    fn depthwise_conv() {
        let (out, w, macs) = infer_layer(
            LayerKind::Conv { kernel: 3, stride: 2, pad: 1, groups: 32 },
            &[Shape::new(32, 112, 112)],
            32,
        );
        assert_eq!(out, Shape::new(32, 56, 56));
        assert_eq!(w, 9 * 32 + 32);
        assert_eq!(macs, 9 * 32 * 56 * 56);
    }

    #[test]
    fn linear_shape() {
        let (out, w, _) = infer_layer(LayerKind::Linear, &[Shape::vec(2048)], 1000);
        assert_eq!(out, Shape::vec(1000));
        assert_eq!(w, 2048 * 1000 + 1000);
    }

    #[test]
    fn global_pool() {
        let (out, w, _) = infer_layer(
            LayerKind::Pool { kernel: 7, stride: 1, kind: PoolKind::GlobalAvg },
            &[Shape::new(2048, 7, 7)],
            0,
        );
        assert_eq!(out, Shape::vec(2048));
        assert_eq!(w, 0);
    }

    #[test]
    fn concat_channels() {
        let (out, ..) = infer_layer(
            LayerKind::Concat,
            &[Shape::new(64, 28, 28), Shape::new(128, 28, 28), Shape::new(32, 28, 28)],
            0,
        );
        assert_eq!(out, Shape::new(224, 28, 28));
    }

    #[test]
    fn upsample_doubles_spatial() {
        let (out, ..) = infer_layer(
            LayerKind::Upsample { factor: 2 },
            &[Shape::new(256, 13, 13)],
            0,
        );
        assert_eq!(out, Shape::new(256, 26, 26));
    }

    #[test]
    fn bits_to_bytes_rounds_up() {
        assert_eq!(bits_to_bytes(3, 4), 2); // 12 bits -> 2 bytes
        assert_eq!(bits_to_bytes(2, 4), 1);
        assert_eq!(bits_to_bytes(10, 8), 10);
        assert_eq!(bits_to_bytes(7, 1), 1);
        assert_eq!(bits_to_bytes(0, 8), 0);
    }

    #[test]
    fn mul_broadcasts_se_gate() {
        let (out, ..) = infer_layer(
            LayerKind::Mul,
            &[Shape::new(96, 14, 14), Shape::vec(96)],
            0,
        );
        assert_eq!(out, Shape::new(96, 14, 14));
    }
}
