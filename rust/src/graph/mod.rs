//! DNN DAG substrate: layers, graphs, inference-graph optimization,
//! liveness (activation working set), and min-cut partitioning.

pub mod dag;
pub mod layer;
pub mod liveness;
pub mod mincut;
pub mod optimize;

pub use dag::{Graph, NodeId};
pub use layer::{ActKind, Layer, LayerKind, PoolKind, Shape};
pub use mincut::{min_cut_split, MinCutSplit};
pub use optimize::{optimize_for_inference, OptimizedGraph};
