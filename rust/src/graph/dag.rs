//! The inference DAG: nodes are [`Layer`]s, edges carry activations.
//!
//! All Auto-Split analyses (potential-split identification, activation
//! working-set / `M^a` computation, min-cut baselines) operate on this
//! structure.

use super::layer::{infer_layer, Layer, LayerKind, Shape};


pub type NodeId = usize;

/// A DNN inference graph (DAG). Node 0 is always the input.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub layers: Vec<Layer>,
    /// `preds[i]` = producers feeding node `i`, in input order.
    pub preds: Vec<Vec<NodeId>>,
    /// `succs[i]` = consumers of node `i`'s output.
    pub succs: Vec<Vec<NodeId>>,
}

impl Graph {
    pub fn new(name: impl Into<String>, input: Shape) -> Self {
        let mut g = Graph { name: name.into(), ..Default::default() };
        let (out, w, macs) = infer_layer(LayerKind::Input, &[input], 0);
        g.layers.push(Layer {
            name: "input".into(),
            kind: LayerKind::Input,
            in_shapes: vec![input],
            out_shape: out,
            weight_count: w,
            macs,
            fused_activation: None,
            folded_bn: false,
        });
        g.preds.push(vec![]);
        g.succs.push(vec![]);
        g
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Append a layer consuming the outputs of `preds`; returns its id.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        kind: LayerKind,
        preds: &[NodeId],
        out_channels: usize,
    ) -> NodeId {
        let in_shapes: Vec<Shape> =
            preds.iter().map(|&p| self.layers[p].out_shape).collect();
        let (out, w, macs) = infer_layer(kind, &in_shapes, out_channels);
        let id = self.layers.len();
        self.layers.push(Layer {
            name: name.into(),
            kind,
            in_shapes,
            out_shape: out,
            weight_count: w,
            macs,
            fused_activation: None,
            folded_bn: false,
        });
        self.preds.push(preds.to_vec());
        self.succs.push(vec![]);
        for &p in preds {
            self.succs[p].push(id);
        }
        id
    }

    /// Ids of nodes with no consumers (graph outputs).
    pub fn outputs(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&i| self.succs[i].is_empty()).collect()
    }

    /// Kahn topological order. Nodes are inserted in construction order so
    /// for builder-produced graphs this is typically `0..n`, but graph
    /// optimization can rewire edges; always sort explicitly.
    pub fn topo_order(&self) -> Vec<NodeId> {
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|i| self.preds[i].len()).collect();
        let mut queue: std::collections::VecDeque<NodeId> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        assert_eq!(order.len(), n, "graph {} has a cycle", self.name);
        order
    }

    /// Validate structural invariants; used by tests and after rewrites.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.len();
        if self.preds.len() != n || self.succs.len() != n {
            return Err("adjacency length mismatch".into());
        }
        for i in 0..n {
            for &p in &self.preds[i] {
                if p >= n {
                    return Err(format!("node {i} pred {p} out of range"));
                }
                if !self.succs[p].contains(&i) {
                    return Err(format!("asymmetric edge {p}->{i}"));
                }
            }
            for &s in &self.succs[i] {
                if !self.preds[s].contains(&i) {
                    return Err(format!("asymmetric edge {i}->{s}"));
                }
            }
            // The input node stores its own shape in `in_shapes` despite
            // having no predecessors.
            if !matches!(self.layers[i].kind, LayerKind::Input)
                && self.layers[i].in_shapes.len() != self.preds[i].len()
            {
                return Err(format!("node {i} in_shapes/preds mismatch"));
            }
        }
        // acyclicity via topo
        let mut indeg: Vec<usize> = (0..n).map(|i| self.preds[i].len()).collect();
        let mut queue: std::collections::VecDeque<NodeId> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(u) = queue.pop_front() {
            seen += 1;
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        if seen != n {
            return Err("cycle detected".into());
        }
        Ok(())
    }

    /// Total parameter elements (`Σ s^w_i`).
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weight_count).sum()
    }

    /// Total MACs for one inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Model size in bytes at `bits` precision.
    pub fn model_bytes(&self, bits: u8) -> usize {
        self.layers.iter().map(|l| l.weight_bytes(bits)).sum()
    }

    /// Input activation element count (raw image volume).
    pub fn input_elems(&self) -> usize {
        self.layers[0].out_shape.volume()
    }

    /// Elements transmitted if the graph is cut after the topo-prefix
    /// `prefix` (a set of node ids, must include node 0): the sum of
    /// `s^a_u` over prefix nodes `u` with at least one consumer outside
    /// the prefix. Each producer is counted once even with multiple
    /// crossing consumers (its output is transmitted once).
    pub fn cut_elems(&self, in_prefix: &[bool]) -> usize {
        let mut total = 0;
        for u in 0..self.len() {
            if !in_prefix[u] {
                continue;
            }
            if self.succs[u].iter().any(|&v| !in_prefix[v]) {
                total += self.layers[u].act_elems();
            }
        }
        total
    }

    /// The set of producer nodes whose activations cross the cut.
    pub fn cut_tensors(&self, in_prefix: &[bool]) -> Vec<NodeId> {
        (0..self.len())
            .filter(|&u| in_prefix[u] && self.succs[u].iter().any(|&v| !in_prefix[v]))
            .collect()
    }

    /// Membership mask for the prefix of `order[..=pos]`.
    pub fn prefix_mask(&self, order: &[NodeId], pos: usize) -> Vec<bool> {
        let mut mask = vec![false; self.len()];
        for &id in &order[..=pos] {
            mask[id] = true;
        }
        mask
    }
}

impl std::fmt::Display for Graph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "{}: {} layers, {:.2}M params, {:.2}G MACs",
            self.name,
            self.len(),
            self.total_weights() as f64 / 1e6,
            self.total_macs() as f64 / 1e9
        )?;
        for (i, l) in self.layers.iter().enumerate() {
            writeln!(
                f,
                "  [{i:3}] {:<24} {:<3} {} <- {:?}",
                l.name,
                l.kind.short_code(),
                l.out_shape,
                self.preds[i]
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::PoolKind;

    fn diamond() -> Graph {
        // input -> a -> {b, c} -> add
        let mut g = Graph::new("diamond", Shape::new(3, 8, 8));
        let a = g.add("a", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 8);
        let b = g.add("b", LayerKind::Conv { kernel: 1, stride: 1, pad: 0, groups: 1 }, &[a], 8);
        let c = g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[a], 8);
        g.add("add", LayerKind::Add, &[b, c], 0);
        g
    }

    #[test]
    fn builds_and_validates() {
        let g = diamond();
        assert!(g.validate().is_ok());
        assert_eq!(g.len(), 5);
        assert_eq!(g.outputs(), vec![4]);
    }

    #[test]
    fn topo_order_is_valid() {
        let g = diamond();
        let order = g.topo_order();
        let mut pos = vec![0; g.len()];
        for (p, &id) in order.iter().enumerate() {
            pos[id] = p;
        }
        for v in 0..g.len() {
            for &p in &g.preds[v] {
                assert!(pos[p] < pos[v]);
            }
        }
    }

    #[test]
    fn cut_counts_producer_once() {
        let g = diamond();
        // prefix {input, a}: a's output feeds both b and c across the cut,
        // but is transmitted once.
        let mut mask = vec![false; g.len()];
        mask[0] = true;
        mask[1] = true;
        assert_eq!(g.cut_elems(&mask), g.layers[1].act_elems());
        assert_eq!(g.cut_tensors(&mask), vec![1]);
    }

    #[test]
    fn cut_with_two_crossing_tensors() {
        let g = diamond();
        // prefix {input, a, b}: both a (feeds c) and b (feeds add) cross.
        let mut mask = vec![false; g.len()];
        for i in [0usize, 1, 2] {
            mask[i] = true;
        }
        assert_eq!(
            g.cut_elems(&mask),
            g.layers[1].act_elems() + g.layers[2].act_elems()
        );
    }

    #[test]
    fn totals() {
        let mut g = Graph::new("t", Shape::new(3, 4, 4));
        g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 4);
        g.add("p", LayerKind::Pool { kernel: 2, stride: 2, kind: PoolKind::Max }, &[1], 0);
        assert_eq!(g.total_weights(), 3 * 9 * 4 + 4);
        assert_eq!(g.model_bytes(8), 3 * 9 * 4 + 4);
        assert_eq!(g.model_bytes(4), (3 * 9 * 4 + 4) / 2);
        assert_eq!(g.input_elems(), 48);
    }
}
