//! Small deterministic RNG (SplitMix64 + Box-Muller) so profiles are
//! reproducible without external crates.

/// SplitMix64: tiny, fast, and good enough for tensor synthesis.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Seed derived from a string (stable FNV-1a hash).
    pub fn from_name(name: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        SplitMix64::new(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Standard normal via Box-Muller. (A paired-output variant was tried
    /// for §Perf and reverted: it perturbs the deterministic streams the
    /// distortion calibration rests on, for a ~5 ms one-off gain.)
    pub fn next_normal(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Laplace(0, b) sample — used for heavy-tailed weight layers.
    pub fn next_laplace(&mut self, b: f64) -> f64 {
        let u = self.next_f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn from_name_is_stable_and_distinct() {
        let a = SplitMix64::from_name("resnet50/conv1").next_u64();
        let a2 = SplitMix64::from_name("resnet50/conv1").next_u64();
        let b = SplitMix64::from_name("resnet50/conv2").next_u64();
        assert_eq!(a, a2);
        assert_ne!(a, b);
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn uniform_range() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn laplace_is_symmetric_heavy_tailed() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_laplace(1.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05);
        // kurtosis of Laplace(1) is 6 (vs 3 for normal)
        let var = xs.iter().map(|x| x * x).sum::<f64>() / n as f64;
        let k = xs.iter().map(|x| x.powi(4)).sum::<f64>() / n as f64 / (var * var);
        assert!(k > 4.0, "kurtosis {k}");
    }
}
