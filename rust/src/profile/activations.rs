//! Activation-statistics helpers shared by calibration and the serving
//! runtime (scale/zero-point computation for the transmission protocol).

/// Summary statistics of a sampled tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorStats {
    pub min: f32,
    pub max: f32,
    pub mean: f32,
    pub std: f32,
    /// Fraction of exact zeros (ReLU sparsity; drives the Table 7
    /// feature-compression advantage).
    pub sparsity: f32,
}

impl TensorStats {
    pub fn compute(xs: &[f32]) -> Self {
        if xs.is_empty() {
            return TensorStats { min: 0.0, max: 0.0, mean: 0.0, std: 0.0, sparsity: 0.0 };
        }
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        let mut sum = 0.0f64;
        let mut zeros = 0usize;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
            sum += x as f64;
            if x == 0.0 {
                zeros += 1;
            }
        }
        let mean = (sum / xs.len() as f64) as f32;
        let var = xs.iter().map(|&x| ((x - mean) as f64).powi(2)).sum::<f64>()
            / xs.len() as f64;
        TensorStats {
            min,
            max,
            mean,
            std: var.sqrt() as f32,
            sparsity: zeros as f32 / xs.len() as f32,
        }
    }

    /// Symmetric quantization scale for `bits` (paper's edge devices use
    /// symmetric integer grids; zero-point 0).
    pub fn symmetric_scale(&self, bits: u8) -> f32 {
        let amax = self.min.abs().max(self.max.abs());
        let qmax = ((1i64 << (bits - 1)) - 1) as f32;
        if amax == 0.0 {
            1.0
        } else {
            amax / qmax
        }
    }

    /// Asymmetric (affine) scale and zero-point covering [min, max].
    pub fn affine_scale_zp(&self, bits: u8) -> (f32, i32) {
        let levels = ((1u64 << bits) - 1) as f32;
        let (lo, hi) = (self.min.min(0.0), self.max.max(0.0));
        let scale = if hi > lo { (hi - lo) / levels } else { 1.0 };
        let zp = (-lo / scale).round() as i32;
        (scale, zp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = TensorStats::compute(&[0.0, 1.0, -1.0, 0.0]);
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 1.0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.sparsity, 0.5);
    }

    #[test]
    fn symmetric_scale_int8() {
        let s = TensorStats::compute(&[-2.0, 2.0]);
        let sc = s.symmetric_scale(8);
        assert!((sc - 2.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn affine_covers_range() {
        let s = TensorStats::compute(&[0.0, 6.0]); // relu6-like
        let (scale, zp) = s.affine_scale_zp(8);
        assert_eq!(zp, 0);
        assert!((scale - 6.0 / 255.0).abs() < 1e-7);
        let s2 = TensorStats::compute(&[-1.0, 3.0]);
        let (sc2, zp2) = s2.affine_scale_zp(4);
        assert!(zp2 > 0);
        assert!(sc2 > 0.0);
    }

    #[test]
    fn empty_tensor_safe() {
        let s = TensorStats::compute(&[]);
        assert_eq!(s.symmetric_scale(8), 1.0);
    }
}
