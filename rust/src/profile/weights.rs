//! Synthetic per-layer weight tensors and the [`ModelProfile`] consumed by
//! the quantization-error machinery.
//!
//! Real post-training-quantization sensitivity varies across layers because
//! weight distributions differ (first/last layers and depthwise convs are
//! notoriously outlier-heavy, large 1x1 projections are benign). We emulate
//! this with a per-layer *distribution family* chosen deterministically from
//! the layer's name and role:
//! * plain convs/linears: Gaussian with fan-in scaling (He init shape)
//! * depthwise convs: Gaussian + Laplace outlier mixture (heavy tails)
//! * first conv & heads: wider dynamic range (scale ×2)
//!
//! Tensors are subsampled to at most [`MAX_SAMPLE`] elements — quantization
//! MSE is a per-element statistic, so a deterministic subsample of a few
//! thousand points estimates it to well under 1% relative error.

use super::rng::SplitMix64;
use crate::graph::{Graph, LayerKind};

/// Cap on sampled elements per tensor (keeps profiling O(n_layers)).
pub const MAX_SAMPLE: usize = 4096;

/// Per-layer sampled tensors for quantization analysis.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    /// Sampled weight values (empty for weight-free layers).
    pub weights: Vec<f32>,
    /// Sampled output-activation values (post-nonlinearity).
    pub activations: Vec<f32>,
    /// True element counts the samples stand for.
    pub weight_count: usize,
    pub act_count: usize,
}

/// Sampled profile for a whole model.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    /// Build the deterministic synthetic profile for `g`.
    pub fn synthesize(g: &Graph) -> Self {
        let layers = (0..g.len()).map(|i| synth_layer(g, i)).collect();
        ModelProfile { layers }
    }
}

fn synth_layer(g: &Graph, id: usize) -> LayerProfile {
    let layer = &g.layers[id];
    let mut rng = SplitMix64::from_name(&format!("{}/{}", g.name, layer.name));

    let weights = if layer.weight_count > 0 && layer.kind.has_weights() {
        let n = layer.weight_count.min(MAX_SAMPLE);
        let fan_in = fan_in(g, id).max(1);
        let sigma = (2.0 / fan_in as f64).sqrt();
        let (outlier_p, outlier_scale, range_scale) = weight_character(g, id, &mut rng);
        (0..n)
            .map(|_| {
                let base = if rng.next_f64() < outlier_p {
                    rng.next_laplace(sigma * outlier_scale)
                } else {
                    rng.next_normal() * sigma
                };
                (base * range_scale) as f32
            })
            .collect()
    } else {
        vec![]
    };

    let activations = {
        let n = layer.act_elems().min(MAX_SAMPLE);
        let relu_like = layer.fused_activation.is_some()
            || matches!(layer.kind, LayerKind::Activation(_));
        // Activation scale grows mildly with depth (BN keeps it near 1).
        let depth_frac = id as f64 / g.len().max(1) as f64;
        let sigma = 1.0 + 0.5 * depth_frac;
        (0..n)
            .map(|_| {
                let x = rng.next_normal() * sigma;
                let v = if relu_like { x.max(0.0) } else { x };
                v as f32
            })
            .collect()
    };

    LayerProfile {
        weights,
        activations,
        weight_count: layer.weight_count,
        act_count: layer.act_elems(),
    }
}

/// (outlier probability, outlier scale, dynamic-range scale) per layer role.
fn weight_character(g: &Graph, id: usize, rng: &mut SplitMix64) -> (f64, f64, f64) {
    let layer = &g.layers[id];
    let depthwise = matches!(layer.kind, LayerKind::Conv { groups, .. } if groups > 1);
    let first = id <= 1;
    let last = g.succs[id].is_empty()
        || g.succs[id].iter().all(|&s| matches!(g.layers[s].kind, LayerKind::Head));
    // A mild random per-layer factor keeps sensitivities from being
    // perfectly uniform across same-shaped layers (Table 10 discussion).
    let jitter = 0.75 + 0.5 * rng.next_f64();
    if depthwise {
        (0.05, 4.0, 1.5 * jitter)
    } else if first || last {
        (0.02, 3.0, 2.0 * jitter)
    } else {
        (0.005, 2.0, 1.0 * jitter)
    }
}

fn fan_in(g: &Graph, id: usize) -> usize {
    let layer = &g.layers[id];
    match layer.kind {
        LayerKind::Conv { kernel, groups, .. } => {
            let cin = layer.in_shapes.first().map(|s| s.c).unwrap_or(1);
            (cin / groups.max(1)) * kernel * kernel
        }
        LayerKind::Linear => layer.in_shapes.first().map(|s| s.volume()).unwrap_or(1),
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{LayerKind, Shape};

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", Shape::new(3, 16, 16));
        let c = g.add("c1", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 8);
        let c2 = g.add("c2", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[c], 8);
        let d = g.add("dw", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 8 }, &[c2], 8);
        g.add("fc", LayerKind::Linear, &[d], 10);
        g
    }

    #[test]
    fn deterministic_profiles() {
        let g = tiny();
        let a = ModelProfile::synthesize(&g);
        let b = ModelProfile::synthesize(&g);
        assert_eq!(a.layers[1].weights, b.layers[1].weights);
        assert_eq!(a.layers[2].activations, b.layers[2].activations);
    }

    #[test]
    fn sample_counts_capped() {
        let g = tiny();
        let p = ModelProfile::synthesize(&g);
        for lp in &p.layers {
            assert!(lp.weights.len() <= MAX_SAMPLE);
            assert!(lp.activations.len() <= MAX_SAMPLE);
        }
        assert_eq!(p.layers[1].weight_count, g.layers[1].weight_count);
    }

    #[test]
    fn depthwise_has_heavier_tails_than_plain() {
        let g = tiny();
        let p = ModelProfile::synthesize(&g);
        let kurt = |xs: &[f32]| {
            let n = xs.len() as f64;
            let m = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
            let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
            xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / n / (var * var)
        };
        // layer 2 is the mid-network plain conv, layer 3 the depthwise
        let plain = kurt(&p.layers[2].weights);
        let dw = kurt(&p.layers[3].weights);
        assert!(dw > plain, "depthwise kurtosis {dw} <= plain {plain}");
    }

    #[test]
    fn relu_activations_nonnegative_when_fused() {
        let mut g = Graph::new("r", Shape::new(3, 8, 8));
        let c = g.add("c", LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 }, &[0], 4);
        g.add("r", LayerKind::Activation(crate::graph::ActKind::Relu), &[c], 0);
        let p = ModelProfile::synthesize(&g);
        assert!(p.layers[2].activations.iter().all(|&x| x >= 0.0));
    }
}
