//! Deterministic synthetic model profiles.
//!
//! The paper profiles *trained* models (weights + sample activations) to
//! obtain per-layer quantization sensitivities. We have no proprietary
//! checkpoints, so this module synthesizes per-layer weight tensors and
//! activation samples from seeded, layer-dependent distributions chosen to
//! reproduce the *sensitivity diversity* real networks exhibit (see
//! DESIGN.md §3). Everything is deterministic: same graph → same profile.

pub mod activations;
pub mod rng;
pub mod weights;

pub use activations::TensorStats;
pub use rng::SplitMix64;
pub use weights::{LayerProfile, ModelProfile};
