//! Small in-tree utilities (the build is offline: no serde/clap/etc.).

pub mod hist;
pub mod json;
pub mod meta;

pub use hist::{HistSnapshot, Histogram};
pub use json::Json;
pub use meta::bench_meta;
