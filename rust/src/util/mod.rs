//! Small in-tree utilities (the build is offline: no serde/clap/etc.).

pub mod json;

pub use json::Json;
