//! Minimal JSON parser/serializer (the build environment has no serde;
//! only artifacts/metadata.json and report files pass through here).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `meta.at(&["graph", "boundary"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                // JSON has no lexeme for inf/NaN; `write!("{n}")` would emit
                // bare `inf` which our own parser (rightly) rejects. Serialize
                // every non-finite value as null so emitted documents always
                // re-parse.
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 1 {
                        out.push(' ');
                    }
                    let _ = write!(out, "\"{}\": ", escape(k));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
        }
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c => vec![c],
        })
        .collect()
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('?'));
                            self.i += 4;
                        }
                        Some(c) => out.push(c as char),
                        None => bail!("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // handle multi-byte UTF-8 transparently
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("bad array at {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("bad object at {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_metadata_like_doc() {
        let doc = r#"{
            "model": "lpr_digit_cnn",
            "graph": {"boundary": [64, 4, 4], "act_bits": 4},
            "scales": [0.01, 0.02],
            "acc": 0.987,
            "ok": true,
            "none": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "lpr_digit_cnn");
        assert_eq!(j.at(&["graph", "act_bits"]).unwrap().as_usize().unwrap(), 4);
        let b = j.at(&["graph", "boundary"]).unwrap().as_arr().unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].as_usize().unwrap(), 64);
        assert!((j.get("acc").unwrap().as_f64().unwrap() - 0.987).abs() < 1e-12);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a": [1, 2.5, "x"], "b": {"c": -3e2}}"#;
        let j = Json::parse(doc).unwrap();
        let s = j.to_string_pretty();
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\"cA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\"cA");
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null_and_reparse() {
        let j = Json::Arr(vec![
            Json::Num(f64::INFINITY),
            Json::Num(f64::NEG_INFINITY),
            Json::Num(f64::NAN),
            Json::Num(1.5),
        ]);
        let s = j.to_string_pretty();
        assert!(!s.contains("inf") && !s.contains("NaN"), "bad tokens in {s}");
        let back = Json::parse(&s).unwrap();
        let a = back.as_arr().unwrap();
        assert_eq!(a[0], Json::Null);
        assert_eq!(a[1], Json::Null);
        assert_eq!(a[2], Json::Null);
        assert_eq!(a[3].as_f64().unwrap(), 1.5);
    }

    #[test]
    fn write_parse_roundtrip_over_edge_case_floats() {
        // Property-style sweep: every emitted document must re-parse, and
        // finite values must survive the trip exactly (f64 Display is
        // shortest-roundtrip in Rust).
        let cases = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            -0.1,
            1e-308,
            -1e-308,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
            1e15,
            1e15 - 1.0,
            -(1e15 - 1.0),
            2.5e17,
            f64::EPSILON,
            std::f64::consts::PI,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        for &x in &cases {
            let mut obj = BTreeMap::new();
            obj.insert("v".to_string(), Json::Num(x));
            let doc = Json::Obj(obj);
            let s = doc.to_string_pretty();
            let back = Json::parse(&s)
                .unwrap_or_else(|e| panic!("{x:?} emitted unparseable JSON {s:?}: {e}"));
            let v = back.get("v").unwrap();
            if x.is_finite() {
                assert_eq!(v.as_f64().unwrap(), x, "value changed through roundtrip: {s}");
            } else {
                assert_eq!(v, &Json::Null, "non-finite must become null: {s}");
            }
        }
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = Json::parse("[-1, 2e3, -4.5e-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1.0);
        assert_eq!(a[1].as_f64().unwrap(), 2000.0);
        assert!((a[2].as_f64().unwrap() + 0.045).abs() < 1e-12);
    }
}
