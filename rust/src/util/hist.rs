//! The one log2 histogram implementation shared by every layer.
//!
//! Two faces over the same bucket layout:
//!
//! * [`Histogram`] — lock-free atomic write side. Request-path threads
//!   (and, since the op profiler, the REFHLO interpreter itself) record
//!   nanosecond durations with a handful of relaxed-cost atomic RMWs.
//! * [`HistSnapshot`] — plain one-pass copy: quantiles, moments,
//!   lossless merging, and non-atomic recording for single-threaded
//!   read-side consumers (`coordinator::metrics::LatencyHistogram` is a
//!   thin view over one of these — there is no second bucket scheme).
//!
//! Layout: exact buckets for 0..15 ns, then 16 linear sub-buckets per
//! power of two for exponents 4..=63 (≤ 1/16 ≈ 6% relative quantile
//! error), covering the full u64 nanosecond range.

/// `16 + 60×16`: exact buckets for 0..15 ns, then 16 linear sub-buckets
/// per power of two for exponents 4..=63.
pub const HIST_BUCKETS: usize = 16 + 60 * 16;

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::time::Duration;

pub(crate) fn bucket_index(ns: u64) -> usize {
    if ns < 16 {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros() as usize; // ≥ 4
    let sub = ((ns >> (e - 4)) & 0xF) as usize;
    16 + (e - 4) * 16 + sub
}

/// Midpoint of the bucket's value range, in nanoseconds.
pub(crate) fn bucket_mid_ns(idx: usize) -> f64 {
    if idx < 16 {
        return idx as f64;
    }
    let b = idx - 16;
    let e = b / 16 + 4;
    let sub = (b % 16) as u64;
    let width = 1u64 << (e - 4);
    ((16 + sub) * width) as f64 + width as f64 / 2.0
}

/// Clamp a seconds value onto the recordable nanosecond range: NaN is
/// rejected (`None`), negatives clamp to zero, +inf to the top.
fn secs_to_ns(s: f64) -> Option<u64> {
    if s.is_nan() {
        return None;
    }
    Some((s.max(0.0) * 1e9).min(u64::MAX as f64) as u64)
}

/// Lock-free duration histogram over nanoseconds (see module docs).
/// Mergeable and snapshot-consistent: quantiles are computed against
/// the bucket sum observed in one pass, never against a
/// separately-read count.
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record a duration given in seconds. NaN is ignored (an undefined
    /// duration must not shift quantiles toward zero), negatives clamp
    /// to zero, and +inf clamps to the top bucket.
    pub fn record_secs(&self, s: f64) {
        if let Some(ns) = secs_to_ns(s) {
            self.record_ns(ns);
        }
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, SeqCst);
        self.sum_ns.fetch_add(ns, SeqCst);
        self.max_ns.fetch_max(ns, SeqCst);
        self.count.fetch_add(1, SeqCst);
    }

    pub fn count(&self) -> u64 {
        self.count.load(SeqCst)
    }

    /// One-pass consistent snapshot of the bucket state.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(SeqCst)).collect(),
            sum_ns: self.sum_ns.load(SeqCst),
            max_ns: self.max_ns.load(SeqCst),
        }
    }
}

/// Plain (non-atomic) copy of a [`Histogram`]'s state: quantiles,
/// moments, lossless merging, and direct single-threaded recording.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    buckets: Vec<u64>,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: vec![0; HIST_BUCKETS], sum_ns: 0, max_ns: 0 }
    }
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns as f64 / n as f64 / 1e9
        }
    }

    pub fn max(&self) -> f64 {
        self.max_ns as f64 / 1e9
    }

    /// Approximate quantile in seconds; `None` when empty (so empty
    /// histograms serialize as `null`, not a fake `0`).
    pub fn quantile_opt(&self, q: f64) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(bucket_mid_ns(i) / 1e9);
            }
        }
        Some(self.max())
    }

    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_opt(q).unwrap_or(0.0)
    }

    /// Non-atomic write side: `n` samples of `ns` nanoseconds at once
    /// (the read-side `LatencyHistogram` records through this).
    pub fn record_ns_n(&mut self, ns: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[bucket_index(ns)] += n;
        self.sum_ns = self.sum_ns.saturating_add(ns.saturating_mul(n));
        self.max_ns = self.max_ns.max(ns);
    }

    /// [`HistSnapshot::record_ns_n`] over seconds, with the same
    /// NaN/negative/+inf policy as [`Histogram::record_secs`].
    pub fn record_secs_n(&mut self, s: f64, n: u64) {
        if let Some(ns) = secs_to_ns(s) {
            self.record_ns_n(ns, n);
        }
    }

    /// Bucket-wise merge (associative and commutative: the layouts are
    /// identical by construction).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_sub_resolution_and_zero() {
        let h = Histogram::default();
        h.record(Duration::ZERO);
        h.record(Duration::from_nanos(3));
        h.record(Duration::from_nanos(15));
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        // sub-16ns values land in their exact buckets
        assert!(s.quantile(0.01) <= 16e-9, "{}", s.quantile(0.01));
        assert!((s.mean() - 6e-9).abs() < 1e-12);
        assert_eq!(s.max(), 15e-9);
    }

    #[test]
    fn histogram_negative_nan_inf() {
        let h = Histogram::default();
        h.record_secs(f64::NAN); // ignored
        h.record_secs(-5.0); // clamps to 0
        h.record_secs(f64::INFINITY); // clamps to the top bucket
        let s = h.snapshot();
        assert_eq!(s.count(), 2, "NaN must not be counted");
        assert!(s.quantile(0.99) > 1e9, "inf must land in the top bucket");
        assert_eq!(s.quantile_opt(0.01).unwrap(), 0.0, "negative clamps to zero");
    }

    #[test]
    fn histogram_quantile_accuracy() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        let p99 = s.quantile(0.99);
        // ≤ 1/16 relative bucket error
        assert!((p50 - 500e-6).abs() / 500e-6 < 0.07, "{p50}");
        assert!((p99 - 990e-6).abs() / 990e-6 < 0.07, "{p99}");
        assert!(p50 <= p99);
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn empty_quantile_is_none() {
        let s = Histogram::default().snapshot();
        assert!(s.quantile_opt(0.5).is_none());
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn merge_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::default();
            for &v in vals {
                h.record_ns(v);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(&[10, 2000]), mk(&[50_000]), mk(&[7, 1_000_000, 12]));
        // (a ∪ b) ∪ c == a ∪ (b ∪ c)
        let mut ab = a.clone();
        ab.merge(&b);
        ab.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab.count(), a_bc.count());
        assert_eq!(ab.sum_ns, a_bc.sum_ns);
        assert_eq!(ab.max_ns, a_bc.max_ns);
        assert_eq!(ab.buckets, a_bc.buckets);
        for q in [0.1, 0.5, 0.9, 0.999] {
            assert_eq!(ab.quantile(q), a_bc.quantile(q));
        }
    }

    #[test]
    fn snapshot_record_matches_atomic_record() {
        let atomic = Histogram::default();
        let mut plain = HistSnapshot::default();
        for v in [0u64, 7, 999, 50_000, 1_000_000_000] {
            atomic.record_ns(v);
            plain.record_ns_n(v, 1);
        }
        let snap = atomic.snapshot();
        assert_eq!(snap.count(), plain.count());
        assert_eq!(snap.sum_ns, plain.sum_ns);
        assert_eq!(snap.max_ns, plain.max_ns);
        assert_eq!(snap.buckets, plain.buckets);
    }

    #[test]
    fn bulk_record_matches_repeated() {
        let mut a = HistSnapshot::default();
        let mut b = HistSnapshot::default();
        for _ in 0..5 {
            a.record_secs_n(3e-3, 1);
        }
        b.record_secs_n(3e-3, 5);
        assert_eq!(a.buckets, b.buckets);
        assert_eq!(a.sum_ns, b.sum_ns);
        assert_eq!(a.count(), b.count());
    }
}
