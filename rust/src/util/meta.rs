//! Provenance metadata for benchmark artifacts.
//!
//! Every `BENCH_*.json` writer attaches one [`bench_meta`] block so a
//! result file is attributable: which commit produced it, when, and
//! under what configuration. Offline build — the git sha comes from
//! shelling out to `git` (best-effort: a missing binary or a non-repo
//! working directory degrades to `"unknown"`, never an error), and the
//! UTC timestamp is derived from `SystemTime` by hand (no chrono).

use super::json::Json;
use std::collections::BTreeMap;
use std::time::{SystemTime, UNIX_EPOCH};

/// The shared `meta` block:
/// `{"bench", "git_sha", "timestamp_utc", "config", "host"}` — the bench
/// name identifies which harness produced the file (the CI artifact set
/// carries several), and the host block (logical cpu count + os) makes
/// latency numbers comparable across machines.
pub fn bench_meta(name: &str, config: &str) -> Json {
    Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str(name.to_string())),
        ("git_sha".to_string(), Json::Str(git_sha())),
        ("timestamp_utc".to_string(), Json::Str(utc_now())),
        ("config".to_string(), Json::Str(config.to_string())),
        ("host".to_string(), host_meta()),
    ]))
}

/// The `host` sub-block: logical CPU count, OS, detected SIMD features,
/// and the kernel variant the default policy dispatches to — so every
/// latency record says which implementation produced it.
fn host_meta() -> Json {
    use crate::runtime::kernels;
    let cpus =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let kind = kernels::KernelKind::default_kind();
    Json::Obj(BTreeMap::from([
        ("cpus".to_string(), Json::Num(cpus as f64)),
        ("os".to_string(), Json::Str(std::env::consts::OS.to_string())),
        ("cpu_features".to_string(), Json::Str(kernels::cpu_features().to_string())),
        ("kernel".to_string(), Json::Str(kernels::resolve(kind).name().to_string())),
    ]))
}

/// Best-effort `git rev-parse --short HEAD`; `"unknown"` when git or the
/// repository is unavailable (e.g. a source tarball build).
fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Current wall time as ISO-8601 UTC (`YYYY-MM-DDTHH:MM:SSZ`).
fn utc_now() -> String {
    let secs = SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0);
    format_utc(secs)
}

/// Format seconds-since-epoch as ISO-8601 UTC. Civil-date conversion via
/// Howard Hinnant's days-from-civil inverse (exact over the u64 range we
/// care about; leap seconds are out of scope for provenance stamps).
fn format_utc(epoch_secs: u64) -> String {
    let days = epoch_secs / 86_400;
    let rem = epoch_secs % 86_400;
    let (h, m, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);

    // civil_from_days, shifted so the era starts 0000-03-01
    let z = days as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097); // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11], March-based
    let d = doy - (153 * mp + 2) / 5 + 1; // [1, 31]
    let month = if mp < 10 { mp + 3 } else { mp - 9 }; // [1, 12]
    let year = if month <= 2 { y + 1 } else { y };
    format!("{year:04}-{month:02}-{d:02}T{h:02}:{m:02}:{s:02}Z")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_epochs_format_correctly() {
        assert_eq!(format_utc(0), "1970-01-01T00:00:00Z");
        // `date -u -d @951827696 +%FT%TZ`
        assert_eq!(format_utc(951_827_696), "2000-02-29T12:34:56Z");
        // leap-year boundary the March-based calendar must get right
        assert_eq!(format_utc(951_868_800), "2000-03-01T00:00:00Z");
        assert_eq!(format_utc(1_754_524_800), "2025-08-07T00:00:00Z");
    }

    #[test]
    fn meta_block_has_all_keys() {
        let m = bench_meta("serving_obsv", "shards=2 policy=shed-newest");
        assert_eq!(m.get("bench").and_then(|v| v.as_str()), Some("serving_obsv"));
        assert_eq!(
            m.get("config").and_then(|v| v.as_str()),
            Some("shards=2 policy=shed-newest")
        );
        let sha = m.get("git_sha").and_then(|v| v.as_str()).unwrap();
        assert!(!sha.is_empty());
        let ts = m.get("timestamp_utc").and_then(|v| v.as_str()).unwrap();
        assert_eq!(ts.len(), 20, "{ts}");
        assert!(ts.ends_with('Z') && ts.contains('T'), "{ts}");
    }

    #[test]
    fn meta_host_block_reports_this_machine() {
        let m = bench_meta("x", "y");
        let host = m.get("host").expect("host block");
        let cpus = host.get("cpus").and_then(|v| v.as_f64()).unwrap();
        assert!(cpus >= 1.0, "{cpus}");
        assert_eq!(
            host.get("os").and_then(|v| v.as_str()),
            Some(std::env::consts::OS)
        );
        let kernel = host.get("kernel").and_then(|v| v.as_str()).unwrap();
        assert!(
            ["scalar", "sse2", "avx2_fma", "neon"].contains(&kernel),
            "{kernel}"
        );
        // cpu_features is informational and may be empty off x86/arm,
        // but the key itself must always be present
        assert!(host.get("cpu_features").and_then(|v| v.as_str()).is_some());
    }
}
