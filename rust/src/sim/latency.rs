//! The latency functions of §3.1: `L^edge`, `L^cloud`, `L^tr`.
//!
//! Per-layer latency = max(compute, off-chip traffic) — the standard
//! double-buffered overlap assumption SCALE-SIM's analytic mode makes —
//! plus a fixed per-layer dispatch overhead.

use super::device::AcceleratorConfig;
use super::memory::memory_seconds;
use super::network::Uplink;
use super::systolic::compute_seconds;
use crate::graph::layer::bits_to_bytes;
use crate::graph::{Graph, NodeId};

/// Per-layer kernel-dispatch overhead (s). Edge runtimes (TFLite-class)
/// pay ~tens of µs per op; the cloud runtime amortizes via graph mode.
pub const EDGE_DISPATCH_S: f64 = 20e-6;
pub const CLOUD_DISPATCH_S: f64 = 5e-6;

/// Latency oracle for a fixed (edge device, cloud device, uplink) triple.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    pub edge: AcceleratorConfig,
    pub cloud: AcceleratorConfig,
    pub uplink: Uplink,
}

impl LatencyModel {
    pub fn new(edge: AcceleratorConfig, cloud: AcceleratorConfig, uplink: Uplink) -> Self {
        LatencyModel { edge, cloud, uplink }
    }

    /// The paper's experimental setup: Eyeriss edge, TPU cloud, 3 Mbps.
    pub fn paper_default() -> Self {
        LatencyModel::new(
            AcceleratorConfig::eyeriss(),
            AcceleratorConfig::tpu(),
            Uplink::paper_default(),
        )
    }

    /// `L^edge_i(b^w_i, b^a_i)`: seconds to run layer `i` on the edge.
    /// Following SCALE-SIM (and §5.1), compute cycles are
    /// precision-independent — the fixed MAC array neither speeds up below
    /// 8 bits nor slows down at 16 — while off-chip data movement scales
    /// with the bit-width. This is what makes float (QDMP/Neurosurgeon)
    /// splits viable and quantized splits strictly better.
    pub fn edge_layer(&self, g: &Graph, i: NodeId, w_bits: u8, a_bits: u8) -> f64 {
        let layer = &g.layers[i];
        if layer.macs == 0 && layer.weight_count == 0 {
            return 0.0;
        }
        let comp = compute_seconds(layer, &self.edge);
        let mem = memory_seconds(layer, &self.edge, w_bits, a_bits);
        comp.max(mem) + EDGE_DISPATCH_S
    }

    /// `L^cloud_i`: cloud executes at its native (FP16) precision.
    pub fn cloud_layer(&self, g: &Graph, i: NodeId) -> f64 {
        let layer = &g.layers[i];
        if layer.macs == 0 && layer.weight_count == 0 {
            return 0.0;
        }
        let b = self.cloud.native_bits;
        let comp = compute_seconds(layer, &self.cloud);
        let mem = memory_seconds(layer, &self.cloud, b, b);
        comp.max(mem) + CLOUD_DISPATCH_S
    }

    /// `L^tr` for transmitting `elems` activation values at `bits` each.
    pub fn transmission(&self, elems: usize, bits: u8) -> f64 {
        self.uplink.transfer_seconds(bits_to_bytes(elems, bits))
    }

    /// Transmission latency of the raw input (`L^tr_0`): 8-bit pixels.
    pub fn raw_input_transmission(&self, g: &Graph) -> f64 {
        self.transmission(g.input_elems(), 8)
    }

    /// Sum of cloud latencies over all layers (the Cloud-Only compute part).
    pub fn cloud_all(&self, g: &Graph) -> f64 {
        (0..g.len()).map(|i| self.cloud_layer(g, i)).sum()
    }

    /// End-to-end Cloud-Only latency: upload raw input + full cloud run.
    pub fn cloud_only(&self, g: &Graph) -> f64 {
        self.raw_input_transmission(g) + self.cloud_all(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{LayerKind, Shape};

    fn small_net() -> Graph {
        let mut g = Graph::new("net", Shape::new(3, 64, 64));
        let mut prev = 0;
        for (i, c) in [16usize, 32, 64].iter().enumerate() {
            prev = g.add(
                format!("c{i}"),
                LayerKind::Conv { kernel: 3, stride: 2, pad: 1, groups: 1 },
                &[prev],
                *c,
            );
        }
        g.add("fc", LayerKind::Linear, &[prev], 10);
        g
    }

    #[test]
    fn edge_slower_than_cloud_per_layer() {
        let g = small_net();
        let m = LatencyModel::paper_default();
        for i in 1..g.len() {
            assert!(m.edge_layer(&g, i, 8, 8) > m.cloud_layer(&g, i));
        }
    }

    #[test]
    fn quantization_reduces_edge_latency_for_memory_bound_layers() {
        // FC layers are memory bound: weight traffic dominates.
        let g = small_net();
        let m = LatencyModel::paper_default();
        let fc = g.len() - 1;
        let l8 = m.edge_layer(&g, fc, 8, 8);
        let l2 = m.edge_layer(&g, fc, 2, 8);
        assert!(l2 < l8, "2-bit weights should cut FC latency: {l2} vs {l8}");
    }

    #[test]
    fn sixteen_bit_no_faster_than_eight() {
        // compute cycles are precision-independent; memory traffic is not,
        // so 16-bit can only be equal (compute-bound) or slower
        // (memory-bound, e.g. the FC layer)
        let g = small_net();
        let m = LatencyModel::paper_default();
        for i in 1..g.len() {
            assert!(m.edge_layer(&g, i, 16, 16) >= m.edge_layer(&g, i, 8, 8));
        }
        let fc = g.len() - 1;
        assert!(m.edge_layer(&g, fc, 16, 16) > m.edge_layer(&g, fc, 8, 8));
    }

    #[test]
    fn transmission_matches_uplink() {
        let m = LatencyModel::paper_default();
        let elems = 100_000;
        let t8 = m.transmission(elems, 8);
        let t4 = m.transmission(elems, 4);
        assert!(t4 < t8);
        assert!(t8 > 0.2); // 100 KB over 3 Mbps is hundreds of ms
    }

    #[test]
    fn cloud_only_dominated_by_upload_at_3mbps() {
        let g = small_net();
        let m = LatencyModel::paper_default();
        let up = m.raw_input_transmission(&g);
        let total = m.cloud_only(&g);
        assert!(up / total > 0.9, "upload {up} of {total}");
    }

    #[test]
    fn input_and_zero_compute_layers_free() {
        let g = small_net();
        let m = LatencyModel::paper_default();
        assert_eq!(m.edge_layer(&g, 0, 8, 8), 0.0);
        assert_eq!(m.cloud_layer(&g, 0), 0.0);
    }
}
