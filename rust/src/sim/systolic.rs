//! Analytic systolic-array cycle model, following SCALE-SIM's [45]
//! "analytical" estimation mode.
//!
//! Every GEMM-like layer (conv via im2col, linear) is tiled over a
//! `R × C` PE array:
//!
//! * **weight-stationary**: a `R × C` weight tile is pinned; `M` input rows
//!   stream through. Per-tile cycles ≈ `R + C + M - 1` (array fill + drain
//!   + stream), tiles = `⌈K/R⌉ · ⌈N/C⌉`.
//! * **output-stationary**: output tile pinned, `K` partial sums
//!   accumulate; per-tile cycles ≈ `K + R + C - 1`, tiles = `⌈M/R⌉ · ⌈N/C⌉`.
//!
//! Non-GEMM layers (pool, add, concat, upsample) are handled by the memory
//! model only (they are data-movement bound on these accelerators).

use super::device::{AcceleratorConfig, Dataflow};
use crate::graph::{Layer, LayerKind};

/// GEMM dimensions of a layer mapped onto the array (im2col convention).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    /// Output spatial positions (rows streamed through the array).
    pub m: usize,
    /// Reduction size (`k·k·C_in/groups`).
    pub k: usize,
    /// Output channels per group.
    pub n: usize,
    /// Independent GEMMs (conv groups).
    pub groups: usize,
}

/// Map a layer to GEMM dims; `None` for non-GEMM layers.
pub fn gemm_dims(layer: &Layer) -> Option<GemmDims> {
    match layer.kind {
        LayerKind::Conv { kernel, groups, .. } => {
            let cin = layer.in_shapes[0].c;
            Some(GemmDims {
                m: layer.out_shape.h * layer.out_shape.w,
                k: (cin / groups) * kernel * kernel,
                n: layer.out_shape.c / groups,
                groups,
            })
        }
        LayerKind::Linear => Some(GemmDims {
            m: 1,
            k: layer.in_shapes[0].volume(),
            n: layer.out_shape.c,
            groups: 1,
        }),
        _ => None,
    }
}

/// Compute cycles for one layer on `dev` (compute only, no memory).
pub fn compute_cycles(layer: &Layer, dev: &AcceleratorConfig) -> u64 {
    let Some(g) = gemm_dims(layer) else {
        // vector op: one lane per column per cycle, generous estimate
        let elems = layer.out_shape.volume() as u64;
        return elems.div_ceil(dev.cols as u64);
    };
    let (r, c) = (dev.rows as u64, dev.cols as u64);
    let (m, k, n) = (g.m as u64, g.k as u64, g.n as u64);
    let per_group = match dev.dataflow {
        Dataflow::WeightStationary => {
            let tiles = k.div_ceil(r) * n.div_ceil(c);
            tiles * (r + c + m - 1)
        }
        Dataflow::OutputStationary => {
            let tiles = m.div_ceil(r) * n.div_ceil(c);
            tiles * (k + r + c - 1)
        }
    };
    per_group * g.groups as u64
}

/// Seconds of pure compute for a layer.
pub fn compute_seconds(layer: &Layer, dev: &AcceleratorConfig) -> f64 {
    compute_cycles(layer, dev) as f64 / dev.freq_hz
}

/// Array (MAC) utilization of a layer: ideal MAC-cycles / modeled cycles.
pub fn utilization(layer: &Layer, dev: &AcceleratorConfig) -> f64 {
    let cycles = compute_cycles(layer, dev);
    if cycles == 0 {
        return 0.0;
    }
    let ideal = layer.macs as f64 / (dev.rows as f64 * dev.cols as f64);
    (ideal / cycles as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Shape};

    fn conv_layer(cin: usize, cout: usize, hw: usize, k: usize, groups: usize) -> Layer {
        let mut g = Graph::new("t", Shape::new(cin, hw, hw));
        let id = g.add(
            "c",
            LayerKind::Conv { kernel: k, stride: 1, pad: k / 2, groups },
            &[0],
            cout,
        );
        g.layers[id].clone()
    }

    #[test]
    fn gemm_dims_conv() {
        let l = conv_layer(64, 128, 56, 3, 1);
        let d = gemm_dims(&l).unwrap();
        assert_eq!(d.m, 56 * 56);
        assert_eq!(d.k, 64 * 9);
        assert_eq!(d.n, 128);
        assert_eq!(d.groups, 1);
    }

    #[test]
    fn big_layer_slower_than_small() {
        let dev = AcceleratorConfig::eyeriss();
        let big = conv_layer(256, 256, 28, 3, 1);
        let small = conv_layer(32, 32, 28, 3, 1);
        assert!(compute_cycles(&big, &dev) > 10 * compute_cycles(&small, &dev));
    }

    #[test]
    fn tpu_much_faster_than_eyeriss() {
        let l = conv_layer(256, 256, 28, 3, 1);
        let e = compute_seconds(&l, &AcceleratorConfig::eyeriss());
        let t = compute_seconds(&l, &AcceleratorConfig::tpu());
        assert!(e > 20.0 * t, "eyeriss {e} vs tpu {t}");
    }

    #[test]
    fn utilization_bounded() {
        let dev = AcceleratorConfig::eyeriss();
        for l in [
            conv_layer(64, 128, 56, 3, 1),
            conv_layer(3, 64, 224, 7, 1),
            conv_layer(32, 32, 7, 1, 32),
        ] {
            let u = utilization(&l, &dev);
            assert!((0.0..=1.0).contains(&u), "{u}");
        }
    }

    #[test]
    fn dense_conv_utilizes_eyeriss_well() {
        // a large dense conv should keep a small array busy
        let dev = AcceleratorConfig::eyeriss();
        let l = conv_layer(256, 256, 56, 3, 1);
        assert!(utilization(&l, &dev) > 0.5);
    }

    #[test]
    fn depthwise_underutilizes_array() {
        // groups shrink per-GEMM work: utilization collapses (known
        // systolic-array weakness SCALE-SIM reproduces)
        let dev = AcceleratorConfig::tpu();
        let dw = conv_layer(256, 256, 28, 3, 256);
        let dense = conv_layer(256, 256, 28, 3, 1);
        assert!(utilization(&dw, &dev) < utilization(&dense, &dev));
    }

    #[test]
    fn vector_op_cycles() {
        let mut g = Graph::new("t", Shape::new(8, 16, 16));
        let id = g.add("add", LayerKind::Add, &[0, 0], 0);
        let l = g.layers[id].clone();
        let dev = AcceleratorConfig::eyeriss();
        assert_eq!(compute_cycles(&l, &dev), (8 * 16 * 16u64).div_ceil(14));
    }
}
