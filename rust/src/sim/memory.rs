//! Off-chip traffic model: the part of layer latency that *does* scale with
//! bit-width (§5.1: "lower bit precision speeds up data movement across
//! offchip and onchip memory, which in turn results in an overall speedup",
//! while MACs stay fixed INT8).

use super::device::AcceleratorConfig;
use crate::graph::layer::bits_to_bytes;
use crate::graph::Layer;

/// Bytes moved over the off-chip interface for one execution of `layer`
/// at `w_bits` / `a_bits` precision.
///
/// Model: input activations are read once, weights are read once (re-read
/// `refetch` times if the combined working tensors exceed the scratchpad),
/// outputs are written once. This is SCALE-SIM's best-case ("all reuse
/// captured on-chip") traffic plus a capacity-miss refetch factor.
pub fn offchip_bytes(layer: &Layer, dev: &AcceleratorConfig, w_bits: u8, a_bits: u8) -> u64 {
    let in_elems: usize = layer.in_shapes.iter().map(|s| s.volume()).sum();
    let in_bytes = bits_to_bytes(in_elems, a_bits) as u64;
    let out_bytes = bits_to_bytes(layer.out_shape.volume(), a_bits) as u64;
    let w_bytes = bits_to_bytes(layer.weight_count, w_bits) as u64;

    let working = in_bytes + out_bytes + w_bytes;
    let refetch = working.div_ceil(dev.on_chip_bytes.max(1) as u64).max(1);
    // capacity misses re-stream the stationary operand
    in_bytes + out_bytes + w_bytes * refetch
}

/// Seconds spent on off-chip transfers for the layer.
pub fn memory_seconds(layer: &Layer, dev: &AcceleratorConfig, w_bits: u8, a_bits: u8) -> f64 {
    offchip_bytes(layer, dev, w_bits, a_bits) as f64 / dev.dram_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, LayerKind, Shape};

    fn conv_layer(cin: usize, cout: usize, hw: usize) -> Layer {
        let mut g = Graph::new("t", Shape::new(cin, hw, hw));
        let id = g.add(
            "c",
            LayerKind::Conv { kernel: 3, stride: 1, pad: 1, groups: 1 },
            &[0],
            cout,
        );
        g.layers[id].clone()
    }

    #[test]
    fn lower_bits_less_traffic() {
        let dev = AcceleratorConfig::eyeriss();
        let l = conv_layer(64, 64, 28);
        let b8 = offchip_bytes(&l, &dev, 8, 8);
        let b4 = offchip_bytes(&l, &dev, 4, 4);
        let b2 = offchip_bytes(&l, &dev, 2, 2);
        assert!(b4 < b8 && b2 < b4);
        // halving bits should roughly halve traffic
        assert!((b4 as f64) / (b8 as f64) < 0.6);
    }

    #[test]
    fn refetch_kicks_in_for_huge_layers() {
        let dev = AcceleratorConfig::eyeriss(); // 192 KB scratchpad
        let big = conv_layer(512, 512, 28); // weights ≈ 2.36M params
        let small = conv_layer(16, 16, 28);
        let big_w = big.weight_bytes(8) as u64;
        let traffic = offchip_bytes(&big, &dev, 8, 8);
        assert!(traffic > 2 * big_w, "expect weight refetch: {traffic}");
        let small_traffic = offchip_bytes(&small, &dev, 8, 8);
        let small_total = (small.weight_bytes(8)
            + small.in_shapes[0].volume()
            + small.out_shape.volume()) as u64;
        assert_eq!(small_traffic, small_total);
    }

    #[test]
    fn memory_seconds_scale_with_bandwidth() {
        let l = conv_layer(64, 64, 28);
        let e = memory_seconds(&l, &AcceleratorConfig::eyeriss(), 8, 8);
        let t = memory_seconds(&l, &AcceleratorConfig::tpu(), 8, 8);
        assert!(e > 10.0 * t);
    }
}
