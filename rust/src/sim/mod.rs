//! Latency substrate: a SCALE-SIM-style analytic accelerator model
//! (Eyeriss edge / TPU cloud, paper Table 1) plus uplink network models.
//!
//! The paper measures latency on the cycle-accurate SCALE-SIM [45]; we
//! reimplement its analytic estimation mode (see DESIGN.md §3 for the
//! substitution argument). The key property preserved is §5.1's: fixed
//! INT8 MACs mean sub-8-bit precision accelerates *data movement only*.

pub mod calib;
pub mod device;
pub mod latency;
pub mod memory;
pub mod network;
pub mod systolic;

pub use calib::{aggregate, CalibRecord, CalibScales, StageCalib, StagePriors};
pub use device::{AcceleratorConfig, Dataflow};
pub use latency::{LatencyModel, CLOUD_DISPATCH_S, EDGE_DISPATCH_S};
pub use network::Uplink;
