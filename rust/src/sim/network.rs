//! Uplink models: `L^tr(bytes)` for the network types the paper considers
//! (§1: BLE, 3G, 5G, WiFi; experiments default to 3 Mbps per Table 1).

/// A point-to-point uplink between the edge device and the cloud.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uplink {
    /// Application-level throughput, bits per second.
    pub bps: f64,
    /// One-way latency added to every transfer, seconds.
    pub rtt_s: f64,
    /// Protocol overhead multiplier on payload bytes (framing, headers).
    pub overhead: f64,
}

impl Uplink {
    pub fn new(bps: f64) -> Self {
        Uplink { bps, rtt_s: 0.01, overhead: 1.05 }
    }

    /// The paper's default experimental uplink (Table 1: 3 Mbps).
    pub fn paper_default() -> Self {
        Uplink::new(3e6)
    }

    pub fn ble() -> Self {
        Uplink { bps: 0.27e6, rtt_s: 0.05, overhead: 1.10 }
    }

    pub fn cellular_3g() -> Self {
        Uplink { bps: 3e6, rtt_s: 0.065, overhead: 1.08 }
    }

    pub fn wifi() -> Self {
        Uplink { bps: 54e6, rtt_s: 0.005, overhead: 1.05 }
    }

    pub fn nr_5g() -> Self {
        Uplink { bps: 100e6, rtt_s: 0.002, overhead: 1.05 }
    }

    pub fn mbps(rate: f64) -> Self {
        Uplink::new(rate * 1e6)
    }

    /// An ad-hoc uplink from Mbps + RTT in ms, with the generic 5%
    /// protocol overhead. The single constructor shared by the plan bank's
    /// network states, bandwidth-trace replay, and `Server::set_link`, so
    /// offline pricing and the live link always agree (named presets carry
    /// their own measured overheads).
    pub fn from_mbps_rtt(mbps: f64, rtt_ms: f64) -> Self {
        Uplink { bps: mbps * 1e6, rtt_s: rtt_ms / 1e3, overhead: 1.05 }
    }

    /// Bandwidth term only: seconds to serialize `bytes` onto the wire,
    /// with protocol overhead but **without** the per-connection RTT.
    /// Linear in `bytes`, so it distributes over a chained batch.
    pub fn payload_seconds(&self, bytes: usize) -> f64 {
        (bytes as f64 * self.overhead * 8.0) / self.bps
    }

    /// Seconds to move `bytes` application bytes to the cloud as one
    /// stand-alone transfer: one RTT plus the bandwidth term.
    pub fn transfer_seconds(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.rtt_s + self.payload_seconds(bytes)
    }

    /// Seconds to move a *chained batch* of transfers that share one
    /// connection round: the RTT is paid **once per batch**, not once per
    /// transfer. This is the single source of truth for batched uplink
    /// cost — `Link::transmit_batch` realizes exactly this charge, and
    /// `prop_invariants` asserts the two agree.
    pub fn batch_seconds(&self, sizes: &[usize]) -> f64 {
        let payload: f64 = sizes.iter().map(|&b| self.payload_seconds(b)).sum();
        if sizes.iter().all(|&b| b == 0) {
            return 0.0;
        }
        self.rtt_s + payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_mbps_for_one_megabyte() {
        let u = Uplink { bps: 3e6, rtt_s: 0.0, overhead: 1.0 };
        let t = u.transfer_seconds(1 << 20);
        // 8.39 Mbit / 3 Mbps ≈ 2.8 s
        assert!((t - 2.796).abs() < 0.01, "{t}");
    }

    #[test]
    fn zero_bytes_free() {
        assert_eq!(Uplink::paper_default().transfer_seconds(0), 0.0);
    }

    #[test]
    fn faster_links_are_faster() {
        let b = 100_000;
        assert!(Uplink::ble().transfer_seconds(b) > Uplink::cellular_3g().transfer_seconds(b));
        assert!(Uplink::cellular_3g().transfer_seconds(b) > Uplink::wifi().transfer_seconds(b));
        assert!(Uplink::wifi().transfer_seconds(b) > Uplink::nr_5g().transfer_seconds(b));
    }

    #[test]
    fn rtt_floors_small_transfers() {
        let u = Uplink::cellular_3g();
        assert!(u.transfer_seconds(1) >= u.rtt_s);
    }

    #[test]
    fn transfer_is_rtt_plus_payload() {
        let u = Uplink::wifi();
        let b = 12_345;
        assert!((u.transfer_seconds(b) - (u.rtt_s + u.payload_seconds(b))).abs() < 1e-15);
    }

    #[test]
    fn batch_pays_rtt_once() {
        let u = Uplink::cellular_3g();
        let sizes = [100usize, 2000, 5, 700];
        let chained = u.batch_seconds(&sizes);
        let individual: f64 = sizes.iter().map(|&b| u.transfer_seconds(b)).sum();
        // one RTT instead of four
        assert!((individual - chained - 3.0 * u.rtt_s).abs() < 1e-12);
        // and the bandwidth term is exactly the sum of payload terms
        let payload: f64 = sizes.iter().map(|&b| u.payload_seconds(b)).sum();
        assert!((chained - (u.rtt_s + payload)).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_free() {
        assert_eq!(Uplink::wifi().batch_seconds(&[]), 0.0);
        assert_eq!(Uplink::wifi().batch_seconds(&[0, 0]), 0.0);
    }
}
