//! Measured-latency calibration: close the predict→measure loop.
//!
//! The plan bank prices every candidate split with the analytic prior
//! (`PlanSpec::predict_s` = edge + cloud + uplink transfer). This module
//! turns PR 7's measured span data into a deterministic
//! [`CalibRecord`]: per-stage scale factors relative to a caller-chosen
//! [`StagePriors`], plus the overhead the analytic model does not price
//! at all (admission, queueing, dispatch, respond), plus the runtime's
//! per-op latency table. `bankgen --calib` then reprices banks with
//! [`CalibScales`] so `predict_s` tracks what the serving pipeline
//! actually measured on this host.
//!
//! Determinism contract: aggregation uses integer nanosecond sums over
//! the span set, so the same spans in any order produce a byte-identical
//! `calib.json` (the CI gate depends on this). Stages with zero samples
//! keep the analytic prior (`scale = 1.0`, `measured_s = null`).

use crate::coordinator::obsv::{
    SpanKind, SpanRecord, STAGE_ADMIT, STAGE_CLOUD, STAGE_DISPATCH, STAGE_EDGE, STAGE_PACK,
    STAGE_QUEUE, STAGE_RESPOND, STAGE_UPLINK,
};
use crate::runtime::OpProfileRow;
use crate::util::Json;
use std::collections::BTreeMap;

const MAGIC: &str = "auto-split-calib-v1";

/// Analytic per-request stage priors (seconds) the measurements are
/// compared against — what `predict_s` charges for each stage under the
/// traffic mix that produced the spans.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagePriors {
    pub edge_s: f64,
    /// The analytic model prices packing at zero (it is part of the
    /// edge partition); kept explicit so a future prior can split it.
    pub pack_s: f64,
    pub uplink_s: f64,
    pub cloud_s: f64,
}

/// One calibrated stage: sample count, measured mean (None when no
/// samples), the prior it is compared against, and the resulting
/// multiplicative scale (1.0 when unmeasured or the prior is zero).
#[derive(Debug, Clone, PartialEq)]
pub struct StageCalib {
    pub count: u64,
    pub measured_s: Option<f64>,
    pub prior_s: f64,
    pub scale: f64,
}

impl StageCalib {
    fn from_sum(sum_ns: u128, count: u64, prior_s: f64) -> StageCalib {
        let measured_s =
            (count > 0).then(|| sum_ns as f64 / count as f64 / 1e9);
        let scale = match measured_s {
            Some(m) if prior_s > 0.0 => m / prior_s,
            _ => 1.0,
        };
        StageCalib { count, measured_s, prior_s, scale }
    }

    fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("count".to_string(), Json::Num(self.count as f64)),
                (
                    "measured_s".to_string(),
                    self.measured_s.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("prior_s".to_string(), Json::Num(self.prior_s)),
                ("scale".to_string(), Json::Num(self.scale)),
            ]
            .into_iter()
            .collect(),
        )
    }

    fn parse(j: &Json) -> Option<StageCalib> {
        let Json::Obj(o) = j else { return None };
        let num = |k: &str| match o.get(k) {
            Some(Json::Num(n)) => Some(*n),
            _ => None,
        };
        Some(StageCalib {
            count: num("count")? as u64,
            measured_s: num("measured_s"),
            prior_s: num("prior_s")?,
            scale: num("scale")?,
        })
    }
}

/// Multiplicative repricing factors extracted from a [`CalibRecord`],
/// applied by `PlanSpec::predict_calibrated_s`. `identity()` leaves the
/// analytic prediction bit-exact (`x * 1.0 + 0.0`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibScales {
    pub edge: f64,
    pub uplink: f64,
    pub cloud: f64,
    /// Additive per-request seconds the analytic model does not price:
    /// pipeline overhead (admit/queue/dispatch/respond) plus packing.
    pub extra_s: f64,
}

impl CalibScales {
    pub fn identity() -> Self {
        CalibScales { edge: 1.0, uplink: 1.0, cloud: 1.0, extra_s: 0.0 }
    }
}

/// Deterministic calibration record (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibRecord {
    /// Keyed `edge`/`pack`/`uplink`/`cloud` (BTreeMap: sorted JSON).
    pub stages: BTreeMap<String, StageCalib>,
    /// Mean per-request seconds spent outside the modeled stages
    /// (admit + queue + dispatch + respond), over completed spans.
    pub overhead_s: f64,
    /// Completed spans aggregated.
    pub e2e_count: u64,
    /// Mean measured end-to-end seconds over completed spans.
    pub e2e_s: f64,
    /// Per-op latency table from the runtime profiler (may be empty
    /// when the run was traced but not profiled).
    pub ops: Vec<OpProfileRow>,
}

/// Aggregate completed spans (and an optional per-op table) into a
/// [`CalibRecord`] against the given priors. Order-independent: every
/// mean comes from integer nanosecond sums.
pub fn aggregate(spans: &[SpanRecord], priors: &StagePriors, ops: &[OpProfileRow]) -> CalibRecord {
    const MODELED: [(&str, usize); 4] = [
        ("edge", STAGE_EDGE),
        ("pack", STAGE_PACK),
        ("uplink", STAGE_UPLINK),
        ("cloud", STAGE_CLOUD),
    ];
    const OVERHEAD: [usize; 4] = [STAGE_ADMIT, STAGE_QUEUE, STAGE_DISPATCH, STAGE_RESPOND];

    let mut sums = [0u128; 4];
    let mut counts = [0u64; 4];
    let mut overhead_ns = 0u128;
    let mut e2e_ns = 0u128;
    let mut done = 0u64;
    for sp in spans.iter().filter(|s| s.kind == SpanKind::Done) {
        done += 1;
        for (slot, &(_, stage)) in MODELED.iter().enumerate() {
            let ns = sp.stage_ns[stage];
            if ns > 0 {
                sums[slot] += ns as u128;
                counts[slot] += 1;
            }
        }
        for &stage in &OVERHEAD {
            overhead_ns += sp.stage_ns[stage] as u128;
        }
        e2e_ns += sp.stage_ns.iter().map(|&n| n as u128).sum::<u128>();
    }

    let prior_of = |name: &str| match name {
        "edge" => priors.edge_s,
        "pack" => priors.pack_s,
        "uplink" => priors.uplink_s,
        _ => priors.cloud_s,
    };
    let stages = MODELED
        .iter()
        .enumerate()
        .map(|(slot, &(name, _))| {
            (name.to_string(), StageCalib::from_sum(sums[slot], counts[slot], prior_of(name)))
        })
        .collect();
    let mean = |ns: u128| if done > 0 { ns as f64 / done as f64 / 1e9 } else { 0.0 };
    CalibRecord {
        stages,
        overhead_s: mean(overhead_ns),
        e2e_count: done,
        e2e_s: mean(e2e_ns),
        ops: ops.to_vec(),
    }
}

impl CalibRecord {
    fn stage(&self, name: &str) -> Option<&StageCalib> {
        self.stages.get(name)
    }

    /// Repricing factors for `predict_calibrated_s`: per-stage scales
    /// (1.0 where unmeasured) plus the additive unmodeled seconds.
    pub fn scales(&self) -> CalibScales {
        let scale = |n: &str| self.stage(n).map(|s| s.scale).unwrap_or(1.0);
        let pack_s =
            self.stage("pack").and_then(|s| s.measured_s).unwrap_or(0.0);
        CalibScales {
            edge: scale("edge"),
            uplink: scale("uplink"),
            cloud: scale("cloud"),
            extra_s: self.overhead_s + pack_s,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("magic".to_string(), Json::Str(MAGIC.to_string())),
                (
                    "stages".to_string(),
                    Json::Obj(
                        self.stages.iter().map(|(k, v)| (k.clone(), v.to_json())).collect(),
                    ),
                ),
                ("overhead_s".to_string(), Json::Num(self.overhead_s)),
                (
                    "e2e".to_string(),
                    Json::Obj(
                        [
                            ("count".to_string(), Json::Num(self.e2e_count as f64)),
                            ("measured_s".to_string(), Json::Num(self.e2e_s)),
                        ]
                        .into_iter()
                        .collect(),
                    ),
                ),
                (
                    "ops".to_string(),
                    Json::Arr(self.ops.iter().map(OpProfileRow::to_json).collect()),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }

    /// Inverse of [`CalibRecord::to_json`]. The magic is required (this
    /// is a CLI input file); stage entries are otherwise tolerant.
    pub fn parse(j: &Json) -> Option<CalibRecord> {
        let Json::Obj(o) = j else { return None };
        match o.get("magic") {
            Some(Json::Str(m)) if m == MAGIC => {}
            _ => return None,
        }
        let mut stages = BTreeMap::new();
        if let Some(Json::Obj(st)) = o.get("stages") {
            for (k, v) in st {
                stages.insert(k.clone(), StageCalib::parse(v)?);
            }
        }
        let num = |k: &str| match o.get(k) {
            Some(Json::Num(n)) => *n,
            _ => 0.0,
        };
        let (e2e_count, e2e_s) = match o.get("e2e") {
            Some(Json::Obj(e)) => {
                let g = |k: &str| match e.get(k) {
                    Some(Json::Num(n)) => *n,
                    _ => 0.0,
                };
                (g("count") as u64, g("measured_s"))
            }
            _ => (0, 0.0),
        };
        let ops = match o.get("ops") {
            Some(Json::Arr(rows)) => rows.iter().filter_map(OpProfileRow::parse).collect(),
            _ => Vec::new(),
        };
        Some(CalibRecord { stages, overhead_s: num("overhead_s"), e2e_count, e2e_s, ops })
    }

    /// Load from a JSON string (CLI convenience).
    pub fn parse_str(text: &str) -> Option<CalibRecord> {
        CalibRecord::parse(&Json::parse(text).ok()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, stage_ns: [u64; 8]) -> SpanRecord {
        SpanRecord { id: 0, kind, t0_ns: 0, stage_ns, ops: Vec::new() }
    }

    fn priors() -> StagePriors {
        StagePriors { edge_s: 1e-3, pack_s: 0.0, uplink_s: 10e-3, cloud_s: 2e-3 }
    }

    #[test]
    fn aggregation_is_order_independent() {
        let mut spans = vec![
            span(SpanKind::Done, [100, 200, 1_000_000, 5_000, 9_000_000, 300, 2_500_000, 50]),
            span(SpanKind::Done, [80, 150, 1_200_000, 6_000, 11_000_000, 250, 1_500_000, 40]),
            span(SpanKind::Shed, [999, 999, 999, 999, 999, 999, 999, 999]), // ignored
        ];
        let a = aggregate(&spans, &priors(), &[]);
        spans.reverse();
        let b = aggregate(&spans, &priors(), &[]);
        assert_eq!(a, b);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "same span set must serialize byte-identically"
        );
        assert_eq!(a.e2e_count, 2, "shed spans are excluded");
        let edge = a.stage("edge").unwrap();
        assert_eq!(edge.count, 2);
        assert!((edge.measured_s.unwrap() - 1.1e-3).abs() < 1e-12);
        assert!((edge.scale - 1.1).abs() < 1e-9, "{}", edge.scale);
    }

    #[test]
    fn zero_sample_stage_keeps_prior() {
        // no uplink time recorded at all (e.g. full-cloud plan)
        let spans =
            vec![span(SpanKind::Done, [10, 20, 500_000, 0, 0, 30, 900_000, 40])];
        let rec = aggregate(&spans, &priors(), &[]);
        let up = rec.stage("uplink").unwrap();
        assert_eq!(up.count, 0);
        assert_eq!(up.measured_s, None);
        assert_eq!(up.scale, 1.0, "unmeasured stage falls back to the prior");
        assert!(rec.to_json().to_string_pretty().contains("null"));
        let s = rec.scales();
        assert_eq!(s.uplink, 1.0);
    }

    #[test]
    fn scales_reprice_to_measured_means() {
        let spans = vec![
            span(SpanKind::Done, [1_000, 2_000, 2_000_000, 10_000, 5_000_000, 500, 4_000_000, 500]),
        ];
        let rec = aggregate(&spans, &priors(), &[]);
        let s = rec.scales();
        // scale × prior reproduces the measured stage mean exactly
        assert!((s.edge * 1e-3 - 2e-3).abs() < 1e-12);
        assert!((s.uplink * 10e-3 - 5e-3).abs() < 1e-12);
        assert!((s.cloud * 2e-3 - 4e-3).abs() < 1e-12);
        // extra_s covers pack + the four unmodeled stages
        assert!((s.extra_s - (10_000. + 1_000. + 2_000. + 500. + 500.) / 1e9).abs() < 1e-15);
        let modeled = s.edge * 1e-3 + s.uplink * 10e-3 + s.cloud * 2e-3 + s.extra_s;
        assert!((modeled - rec.e2e_s).abs() < 1e-12, "calibrated sum matches measured e2e");
    }

    #[test]
    fn json_roundtrips_and_requires_magic() {
        let spans =
            vec![span(SpanKind::Done, [10, 20, 500_000, 400, 3_000_000, 30, 900_000, 40])];
        let rec = aggregate(&spans, &priors(), &[]);
        let text = rec.to_json().to_string_pretty();
        let back = CalibRecord::parse_str(&text).unwrap();
        assert_eq!(back.e2e_count, rec.e2e_count);
        assert_eq!(back.stages, rec.stages);
        assert_eq!(back.to_json().to_string_pretty(), text, "parse∘to_json is identity");
        assert!(CalibRecord::parse_str("{\"magic\": \"wrong\"}").is_none());
    }

    #[test]
    fn empty_span_set_is_all_priors() {
        let rec = aggregate(&[], &priors(), &[]);
        assert_eq!(rec.e2e_count, 0);
        assert_eq!(rec.scales(), CalibScales { edge: 1.0, uplink: 1.0, cloud: 1.0, extra_s: 0.0 });
    }
}
