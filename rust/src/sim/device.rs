//! Accelerator configurations (paper Table 1) for the SCALE-SIM-style
//! analytic latency model.



/// Systolic-array dataflow, following SCALE-SIM's taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Weights pinned in PEs, inputs streamed (TPU-style).
    WeightStationary,
    /// Partial sums pinned (Eyeriss-adjacent analytic approximation).
    OutputStationary,
}

/// One accelerator: a `rows × cols` systolic MAC array plus an on-chip
/// scratchpad and an off-chip memory channel.
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorConfig {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub freq_hz: f64,
    /// On-chip (scratchpad/SRAM) bytes — Table 1 "On-chip memory".
    pub on_chip_bytes: usize,
    /// Off-chip (DRAM) bytes — Table 1 "Off-chip memory".
    pub off_chip_bytes: usize,
    /// Off-chip bandwidth, bytes/sec — Table 1 "Bandwidth".
    pub dram_bw: f64,
    pub dataflow: Dataflow,
    /// MAC datapath width in bits: the paper's edge devices have fixed
    /// INT8 MAC units, so sub-8-bit precision does NOT speed up compute
    /// (§5.1) — only data movement scales with bit-width.
    pub mac_bits: u8,
    /// Native arithmetic bit-width of the *cloud* execution (FP16 in the
    /// paper's CLOUD16 baseline).
    pub native_bits: u8,
}

impl AcceleratorConfig {
    /// Eyeriss [9] as configured in SCALE-SIM and the paper's Table 1:
    /// 12×14 PE array, 192 KB on-chip, 4 GB off-chip, 1 GB/s, ~34 GOPs.
    pub fn eyeriss() -> Self {
        AcceleratorConfig {
            name: "eyeriss",
            rows: 12,
            cols: 14,
            freq_hz: 200e6,
            on_chip_bytes: 192 * 1024,
            off_chip_bytes: 4 << 30,
            dram_bw: 1e9,
            dataflow: Dataflow::OutputStationary,
            mac_bits: 8,
            native_bits: 8,
        }
    }

    /// Cloud TPU per Table 1: 256×256 MXU, 28 MB on-chip, 16 GB HBM,
    /// 13 GB/s (SCALE-SIM config), ~96 TOPs peak.
    pub fn tpu() -> Self {
        AcceleratorConfig {
            name: "tpu",
            rows: 256,
            cols: 256,
            freq_hz: 700e6,
            on_chip_bytes: 28 << 20,
            off_chip_bytes: 16usize << 30,
            dram_bw: 13e9,
            dataflow: Dataflow::WeightStationary,
            mac_bits: 16,
            native_bits: 16,
        }
    }

    /// Hi3516E-class camera SoC (the §5.5 LPR edge device): a small CPU/NPU
    /// with far less parallelism than Eyeriss-class research silicon.
    pub fn hi3516e() -> Self {
        AcceleratorConfig {
            name: "hi3516e",
            rows: 8,
            cols: 8,
            freq_hz: 900e6,
            on_chip_bytes: 512 << 20, // paper: 512MB on-chip (system RAM)
            off_chip_bytes: 1 << 30,
            dram_bw: 1.6e9,
            dataflow: Dataflow::OutputStationary,
            mac_bits: 8,
            native_bits: 8,
        }
    }

    /// Peak MACs/sec of the array.
    pub fn peak_macs_per_sec(&self) -> f64 {
        self.rows as f64 * self.cols as f64 * self.freq_hz
    }

    /// Peak ops/sec (1 MAC = 2 ops), for roofline reporting.
    pub fn peak_ops(&self) -> f64 {
        2.0 * self.peak_macs_per_sec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_configs() {
        let e = AcceleratorConfig::eyeriss();
        assert_eq!(e.on_chip_bytes, 192 * 1024);
        assert_eq!(e.dram_bw, 1e9);
        // 12*14*200MHz*2 = 67.2 GOPs — same order as Table 1's 34 GOPs
        assert!(e.peak_ops() > 30e9 && e.peak_ops() < 100e9);

        let t = AcceleratorConfig::tpu();
        assert_eq!(t.on_chip_bytes, 28 << 20);
        // 256*256*700MHz*2 ≈ 91.8 TOPs ~ Table 1's 96 TOPs
        assert!(t.peak_ops() > 80e12 && t.peak_ops() < 100e12);
    }

    #[test]
    fn cloud_is_much_faster_than_edge() {
        assert!(
            AcceleratorConfig::tpu().peak_ops()
                > 500.0 * AcceleratorConfig::eyeriss().peak_ops()
        );
    }
}
