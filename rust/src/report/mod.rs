//! Table/figure text rendering shared by the benches (the paper artifacts
//! are regenerated as aligned-text tables + CSV series).

/// A simple aligned-column table printer.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV form for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds as adaptive ms/s text.
pub fn fmt_latency(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}ms", s * 1e3)
    }
}

/// Format bytes adaptively.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.1}MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1024 {
        format!("{:.1}KB", b as f64 / 1024.0)
    } else {
        format!("{b}B")
    }
}

/// Micro-benchmark helper for the harness-less bench targets (criterion is
/// unavailable offline): warm up, run `iters` timed iterations, report
/// min/mean/max seconds.
pub struct BenchStats {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
    pub iters: usize,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {} / mean {} / max {} over {} iters",
            fmt_latency(self.min),
            fmt_latency(self.mean),
            fmt_latency(self.max),
            self.iters
        )
    }
}

pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchStats { min, mean, max, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["model", "latency"]);
        t.row(&["resnet50".into(), "1.2s".into()]);
        t.row(&["x".into(), "34ms".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("resnet50"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_latency(2.5), "2.50s");
        assert_eq!(fmt_latency(0.0123), "12.3ms");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 << 20), "3.0MB");
    }
}
